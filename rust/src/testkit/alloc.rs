//! Counting global-allocator shim for allocation ablations.
//!
//! A thin wrapper over the system allocator that counts allocation
//! events and bytes through two relaxed atomics.  Benches that want to
//! measure allocations install it per-binary:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: CountingAlloc = CountingAlloc::new();
//! ...
//! let before = CountingAlloc::snapshot();
//! hot_path();
//! let (allocs, bytes) = CountingAlloc::since(before);
//! ```
//!
//! Deallocations are uncounted (free is cheap and symmetric); `realloc`
//! counts as one event with the *new* size, which slightly overstates
//! growth-heavy code — fine for an ablation that compares two modes
//! under the same accounting.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Counting wrapper over [`std::alloc::System`].
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }

    /// Cumulative (allocation events, bytes requested) so far.
    pub fn snapshot() -> (u64, u64) {
        (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
    }

    /// Delta since an earlier [`snapshot`](CountingAlloc::snapshot).
    pub fn since(before: (u64, u64)) -> (u64, u64) {
        let (a, b) = Self::snapshot();
        (a.saturating_sub(before.0), b.saturating_sub(before.1))
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

// Safety: delegates every operation to `System`; the counters are
// relaxed atomics with no allocation of their own.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The allocator isn't installed in lib tests (that would tax every
    // test); counters just start at zero and snapshots are monotonic.
    #[test]
    fn snapshots_are_monotonic() {
        let a = CountingAlloc::snapshot();
        let b = CountingAlloc::snapshot();
        assert!(b.0 >= a.0 && b.1 >= a.1);
        assert_eq!(CountingAlloc::since(b).0, CountingAlloc::snapshot().0 - b.0);
    }
}
