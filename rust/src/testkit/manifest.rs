//! Synthetic manifest writer: a minimal, valid `manifest.json` so the
//! serving stack (registry, coordinator, sim engine) can be exercised
//! end-to-end without the Python AOT toolchain or any HLO artifacts.
//!
//! The written manifest passes `Manifest::load`'s structural validation
//! (one stage, empty param/op tables, empty golden index) and carries
//! exactly what the sim engine and the coordinator read: `model`,
//! `input_hw`, `num_classes`, `batch_sizes`.

use anyhow::{Context, Result};
use std::path::Path;

/// Write `<dir>/manifest.json` describing a synthetic model named
/// `model` with the given class count, square input size, and compiled
/// batch sizes.  Creates `dir` if needed; overwrites an existing
/// manifest (that is the point for hot-reload tests).
pub fn write_synthetic(
    dir: &Path,
    model: &str,
    num_classes: usize,
    input_hw: usize,
    batch_sizes: &[usize],
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let sizes = batch_sizes
        .iter()
        .map(|b| b.to_string())
        .collect::<Vec<_>>()
        .join(",");
    // Built by hand rather than through util::json so the output shape is
    // obvious at a glance; keys mirror python/compile/aot.py's manifest.
    let text = format!(
        r#"{{
  "model": "{model}",
  "input_hw": {input_hw},
  "input_channels": 3,
  "num_classes": {num_classes},
  "attenuation": 1.0,
  "batch_sizes": [{sizes}],
  "params": [],
  "params_q8": [],
  "scales": {{}},
  "stages": [
    {{
      "index": 0,
      "name": "sim",
      "params": [],
      "in_shape": [{input_hw}, {input_hw}, 3],
      "out_shape": [{num_classes}],
      "artifacts": {{}}
    }}
  ],
  "probe_stages": [],
  "full": {{}},
  "ops": [],
  "quant_ops": [],
  "golden": {{
    "input": "",
    "probs": "",
    "probs_q8": "",
    "stages": [],
    "top1": 0,
    "top1_q8": 0
  }}
}}
"#
    );
    std::fs::write(dir.join("manifest.json"), text)
        .with_context(|| format!("writing {}", dir.join("manifest.json").display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn synthetic_manifest_loads_and_validates() {
        let dir = std::env::temp_dir().join(format!(
            "zuluko_testkit_manifest_{}",
            std::process::id()
        ));
        write_synthetic(&dir, "synth-a", 1000, 227, &[1, 2, 4]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "synth-a");
        assert_eq!(m.input_hw, 227);
        assert_eq!(m.num_classes, 1000);
        assert_eq!(m.batch_sizes, vec![1, 2, 4]);
        assert!(m.params.is_empty());
        // Overwrite in place (the hot-reload path).
        write_synthetic(&dir, "synth-b", 10, 227, &[1]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "synth-b");
        assert_eq!(m.num_classes, 10);
    }
}
