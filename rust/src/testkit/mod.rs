//! Test substrate: deterministic PRNG, mini property-testing framework,
//! and a counting-allocator shim for allocation ablations.
//! (rand/proptest are not dependencies — DESIGN.md §Substitutions.)

pub mod alloc;
pub mod manifest;
pub mod prop;
pub mod rng;
pub mod sched;
