//! Test substrate: deterministic PRNG + mini property-testing framework.
//! (rand/proptest are not dependencies — DESIGN.md §Substitutions.)

pub mod prop;
pub mod rng;
