//! xorshift64* PRNG — deterministic, dependency-free randomness for
//! workload generation and property tests (rand is not a dependency).

/// Small, fast, seedable PRNG.  Not cryptographic (doesn't need to be).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Avoid the all-zeros fixed point.
        Rng {
            state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform usize in [0, n).  n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Exponentially distributed inter-arrival gap with the given rate
    /// (events/sec) — the Poisson workload generator's core.
    pub fn exp_gap_secs(&mut self, rate: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -u.ln() / rate
    }

    /// Coin flip with probability p of true.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn below_and_range_in_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }

    #[test]
    fn exp_gap_mean_close_to_inverse_rate() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp_gap_secs(50.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.02).abs() < 0.002, "mean gap {mean}");
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}
