//! Mini property-testing framework (proptest is not a dependency).
//!
//! A property runs against N generated cases; on failure the input is
//! shrunk greedily (halving / decrementing integer fields, shrinking
//! vectors) before reporting.  Coordinator invariants (DESIGN.md §6) are
//! tested with this in `rust/tests/`.
//!
//! ```ignore
//! prop_check(100, 42, gen_vec_usize(0..50, 0..10), |case| {
//!     // return Err(msg) to fail
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// A generator produces a case from an Rng; a shrinker yields smaller
/// candidate cases.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of `v` (tried in order).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `prop` on `n` generated cases.  Panics with the (shrunk) failing
/// case and message on failure.
pub fn prop_check<G: Gen>(
    n: usize,
    seed: u64,
    gen: G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = gen.generate(&mut rng);
        if let Err(msg) = prop(&case) {
            // Greedy shrink: repeatedly take the first shrink that still fails.
            let mut cur = case;
            let mut cur_msg = msg;
            'outer: loop {
                for cand in gen.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed on case {i} (shrunk): {cur:?}\n  reason: {cur_msg}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// usize in [lo, hi] inclusive; shrinks toward lo.
pub struct GenUsize {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for GenUsize {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in [lo, hi); shrinks toward lo.
pub struct GenF64 {
    pub lo: f64,
    pub hi: f64,
}

impl Gen for GenF64 {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.lo {
            vec![self.lo, self.lo + (*v - self.lo) / 2.0]
        } else {
            vec![]
        }
    }
}

/// Vec of usizes; shrinks by halving length, then shrinking elements.
pub struct GenVecUsize {
    pub len_lo: usize,
    pub len_hi: usize,
    pub lo: usize,
    pub hi: usize,
}

impl Gen for GenVecUsize {
    type Value = Vec<usize>;
    fn generate(&self, rng: &mut Rng) -> Vec<usize> {
        let len = rng.range(self.len_lo, self.len_hi);
        (0..len).map(|_| rng.range(self.lo, self.hi)).collect()
    }
    fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if v.len() > self.len_lo {
            out.push(v[..v.len() / 2.max(self.len_lo)].to_vec());
            let mut one_less = v.clone();
            one_less.pop();
            out.push(one_less);
        }
        // Shrink first non-lo element.
        if let Some(idx) = v.iter().position(|&e| e > self.lo) {
            let mut smaller = v.clone();
            smaller[idx] = self.lo;
            out.push(smaller);
        }
        out.retain(|c| c.len() >= self.len_lo);
        out
    }
}

/// Pair generator from two independent generators.
pub struct GenPair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for GenPair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check(200, 1, GenUsize { lo: 0, hi: 100 }, |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        prop_check(200, 2, GenUsize { lo: 0, hi: 100 }, |&v| {
            if v < 50 {
                Ok(())
            } else {
                Err(format!("{v} >= 50"))
            }
        });
    }

    #[test]
    fn shrinking_reaches_minimal_counterexample() {
        // Property "v < 37" fails minimally at 37; check the panic message
        // carries the shrunk value.
        let result = std::panic::catch_unwind(|| {
            prop_check(500, 3, GenUsize { lo: 0, hi: 1000 }, |&v| {
                if v < 37 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("37"), "expected minimal 37 in: {msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        prop_check(
            100,
            4,
            GenVecUsize { len_lo: 1, len_hi: 8, lo: 2, hi: 5 },
            |v| {
                if v.is_empty() || v.len() > 8 {
                    return Err(format!("len {}", v.len()));
                }
                if v.iter().any(|&e| !(2..=5).contains(&e)) {
                    return Err("element out of range".into());
                }
                Ok(())
            },
        );
    }
}
