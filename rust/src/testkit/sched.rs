//! Shared scheduler-level test fixtures: a synthetic-manifest
//! [`ExecCtx`], a sim [`WorkSource`], and a dummy [`Request`] — used by
//! the scheduler/router unit tests and `tests/coordinator_props.rs` so
//! the (brand-new, still-evolving) `WorkSource`/`ExecCtx` shapes have
//! one constructor to keep in sync instead of three copies.
//!
//! These fixtures never execute an engine: requests carry a tiny 1×1×3
//! tensor and the manifest describes an 8×8 sim model, which is enough
//! for admission, scheduling, and drain logic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::scheduler::{ExecCtx, QueueKey, WorkSource};
use crate::coordinator::Request;
use crate::engine::EngineKind;
use crate::policy::{PolicyCtx, Slo};
use crate::registry::ModelCounters;
use crate::runtime::Manifest;
use crate::tensor::{PooledTensor, TensorPool};

/// Unique per-fixture suffix: two tests reusing a model name must not
/// race on the same manifest file (fs::write is not atomic).
static SEQ: AtomicU64 = AtomicU64::new(0);

/// An [`ExecCtx`] over a fresh synthetic sim manifest (8×8 input, 10
/// classes, batch sizes [1, 2, 4]; pooling disabled, cache disabled).
pub fn sim_exec(model: &str, generation: u64) -> Arc<ExecCtx> {
    let dir = std::env::temp_dir().join(format!(
        "zuluko_fixture_{model}_{generation}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    crate::testkit::manifest::write_synthetic(&dir, model, 10, 8, &[1, 2, 4])
        .unwrap();
    Arc::new(ExecCtx {
        model: Arc::from(model),
        generation,
        manifest: Manifest::load(&dir).unwrap(),
        arena: TensorPool::disabled(),
        ctx: Arc::new(PolicyCtx::new(0.2, 0)),
        counters: Arc::new(ModelCounters::default()),
        stage_hist: Arc::new(crate::obs::StageHist::new()),
        snapshot: None,
        snapshots_on: false,
    })
}

/// A generation-1 sim [`WorkSource`] over a fresh bounded queue of
/// `cap` slots (max_batch 4, zero batch window, fills the cache).
pub fn sim_source(model: &str, weight: f64, cap: usize) -> Arc<WorkSource> {
    Arc::new(WorkSource::new(
        QueueKey {
            model: Arc::from(model),
            generation: 1,
            engine: EngineKind::Sim,
        },
        Arc::new(BoundedQueue::new(cap)),
        BatchPolicy::new(4, Duration::ZERO, &[1, 2, 4]),
        weight,
        true,
        sim_exec(model, 1),
    ))
}

/// Count live threads of this process whose name starts with `prefix`
/// (Linux /proc; the serving stack is Linux-first — see
/// metrics::sysmon).  Counting by name isolates the measurement from
/// the caller's own threads.  Note the kernel truncates comm to 15
/// chars, so prefixes must stay shorter than that (e.g.
/// "zuluko-runtime-0" reads back as "zuluko-runtime-").
pub fn threads_named(prefix: &str) -> usize {
    std::fs::read_dir("/proc/self/task")
        .unwrap()
        .filter_map(|t| std::fs::read_to_string(t.ok()?.path().join("comm")).ok())
        .filter(|name| name.trim_end().starts_with(prefix))
        .count()
}

/// A dummy request (1×1×3 pixels, reply receiver discarded).  Only the
/// id and SLO matter to the scheduling layer under test.
pub fn dummy_request(id: u64, deadline_ms: Option<f64>) -> Request {
    let pool = TensorPool::disabled();
    let (tx, _rx) = mpsc::channel();
    Request {
        id,
        image: PooledTensor::new(&[1, 1, 3], pool.lease(3)).unwrap(),
        submitted: Instant::now(),
        slo: match deadline_ms {
            Some(ms) => Slo::with_deadline_ms(ms),
            None => Slo::default(),
        },
        cache_key: None,
        wire_key: None,
        reply: crate::coordinator::ReplySink::channel(tx),
        span: crate::obs::Span::default(),
    }
}
