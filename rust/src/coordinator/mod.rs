//! The serving coordinator — L3's composition root.
//!
//! ```text
//! submit(model?, image, slo)
//!          │
//!          ▼
//!   registry.resolve(model) ──unknown──> structured UnknownModel reject
//!          │                             (never a default-model fallback)
//!          ▼  GenerationLease (RAII pin on one model generation)
//!     per-model cache ──hit──> immediate Response
//!          │
//!          ▼
//!     selector (predicted completion vs deadline, per engine queue)
//!          │                        └──none fits──> structured shed
//!     ┌────┴─────┐
//!     ▼          ▼
//!  acl queue  quant queue      (one bounded queue per (model, engine)
//!     │          │              generation, registered with the
//!     └────┬─────┘              process-wide scheduler)
//!          ▼
//!  shared worker runtime: a FIXED fleet of threads (default = core
//!  count) pulls the next queue by deadline urgency then weighted fair
//!  share, executes the batch on an LRU-cached engine replica, feeds
//!  the generation's predictor + response cache
//!          │
//!          ▼
//!  per-request Response (carries the model name) via mpsc reply channel
//! ```
//!
//! Invariants (tested in rust/tests/coordinator_props.rs,
//! rust/tests/policy_props.rs, rust/tests/registry_props.rs, and
//! rust/tests/scheduler_props.rs):
//! * every admitted request gets exactly one Response (success, error,
//!   or a structured deadline rejection) — never a silent drop;
//! * rejected/shed requests are reported as rejections;
//! * FIFO within a queue among equal urgency;
//! * batch sizes ∈ supported artifact sizes;
//! * results are independent of batch packing;
//! * cache hits are bit-identical to the cold inference that filled them;
//! * cache hits never cross models or weight generations;
//! * a hot reload never drops an in-flight request (old generation
//!   drains before its pooled tensors / worker replicas are released);
//! * total worker threads equal the configured runtime size regardless
//!   of model count or concurrent reloads, and a saturating hot model
//!   cannot starve a cold model's deadlined requests.

pub mod batcher;
pub mod queue;
pub mod router;
pub mod scheduler;
pub mod worker;

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::metrics::Histogram;
use crate::policy::{CachedResult, ModelPolicySnapshot, PolicySnapshot, Slo};
use crate::registry::{GenerationLease, ModelRegistry, ReloadReport};
use crate::tensor::{PoolStats, PooledTensor, Tensor, TensorPool};

use scheduler::{QueueDepthRow, Runtime, WorkerOccupancyRow};
use worker::{SharedStats, WorkerReport};

/// One inference request (image already preprocessed, living in a
/// pooled lease so its buffer is recycled on completion).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub image: PooledTensor,
    pub submitted: Instant,
    /// Deadline + priority; default is best-effort.
    pub slo: Slo,
    /// Content hash for response-cache fill (None when caching is off).
    pub cache_key: Option<u64>,
    /// Pre-decode hash of the raw image spec (None when caching is off
    /// or the spec isn't self-describing) — filled alongside
    /// `cache_key` so repeat requests skip decode entirely.
    pub wire_key: Option<u64>,
    pub reply: mpsc::Sender<Response>,
}

/// One inference response (top-k + latency breakdown).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub top1: usize,
    pub top5: Vec<(usize, f32)>,
    /// submit -> batch formed.
    pub queue_ms: f64,
    /// engine.infer wall time for the whole batch.
    pub exec_ms: f64,
    /// submit -> response.
    pub total_ms: f64,
    pub batch_size: usize,
    pub worker: usize,
    /// Which engine served this ("cache" for a cache hit, "" on error).
    pub engine: &'static str,
    /// Which registry model served this ("" on pre-resolution errors).
    pub model: Arc<str>,
    /// True when served from the response cache (no inference ran).
    pub cached: bool,
    /// Machine-matchable error class ("error", "shed"; "" when ok).
    pub kind: &'static str,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: &str) -> Response {
        Response {
            id,
            top1: 0,
            top5: Vec::new(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms: 0.0,
            batch_size: 0,
            worker: usize::MAX,
            engine: "",
            model: Arc::from(""),
            cached: false,
            kind: "error",
            error: Some(msg.to_string()),
        }
    }

    /// Structured rejection for an admitted request whose deadline passed
    /// while it waited in queue (same machine-matchable kind as an
    /// admission-time shed).
    pub fn shed_expired(id: u64, msg: &str) -> Response {
        Response {
            kind: "shed",
            ..Response::error(id, msg)
        }
    }

    pub(crate) fn cache_hit(id: u64, hit: &CachedResult, total_ms: f64) -> Response {
        Response {
            id,
            top1: hit.top1,
            top5: hit.top5.clone(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms,
            batch_size: 0,
            worker: usize::MAX,
            engine: "cache",
            model: Arc::from(""),
            cached: true,
            kind: "",
            error: None,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Submission failure modes (backpressure + SLO + registry surface).
#[derive(Debug, PartialEq)]
pub enum SubmitError {
    /// All worker queues full — retry later (the embedded device is saturated).
    Overloaded,
    /// No engine variant is predicted to finish inside the deadline —
    /// shed at admission instead of serving a doomed request.
    Shed {
        /// Best (smallest) margin-adjusted completion prediction, ms.
        predicted_ms: f64,
        /// The request's full deadline budget, ms.
        deadline_ms: f64,
    },
    /// Coordinator shutting down (or the addressed generation was
    /// retired mid-swap — callers may re-resolve and retry once).
    Closed,
    /// Input had the wrong shape.
    BadInput(String),
    /// The request addressed a model the registry does not know.  A
    /// structured reject — never a silent fallback to the default model.
    UnknownModel(String),
    /// The model is registered but its generation could not be built
    /// (bad artifacts, engine build failure).
    ModelUnavailable { model: String, reason: String },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "overloaded"),
            SubmitError::Shed {
                predicted_ms,
                deadline_ms,
            } => write!(
                f,
                "overloaded: predicted {predicted_ms:.0}ms exceeds \
                 deadline {deadline_ms:.0}ms on every engine"
            ),
            SubmitError::Closed => write!(f, "closed"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
            SubmitError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            SubmitError::ModelUnavailable { model, reason } => {
                write!(f, "model '{model}' unavailable: {reason}")
            }
        }
    }
}

/// Per-model row in a [`StatsSnapshot`].
#[derive(Debug, Clone)]
pub struct ModelStatsSnapshot {
    pub model: String,
    /// Generation currently serving (0 = none; a failed reload never
    /// shows up here — only published generations count).
    pub generation: u64,
    /// Whether engine pools are currently built for this model.
    pub loaded: bool,
    /// Whether this is the default model (serves `model`-less requests).
    pub is_default: bool,
    pub completed: u64,
    pub images: u64,
    pub rejected: u64,
    /// Current generation's response-cache hits/misses (0 when unloaded;
    /// resets on reload — new weights mean a cold cache).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Live stats snapshot.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub images: u64,
    pub queued: usize,
    pub latency_summary: (f64, f64, f64, f64, f64),
    pub mean_batch: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Requests shed at admission by the SLO selector.
    pub shed_predicted: u64,
    /// Admitted requests shed in-queue after their deadline passed.
    pub shed_expired: u64,
    /// Tensor-arena counters (hit/miss/returned/dropped/buffers),
    /// summed across loaded model generations.
    pub pool: PoolStats,
    /// Per-model breakdown, in registry order.
    pub models: Vec<ModelStatsSnapshot>,
    /// Shared-runtime worker occupancy, one row per runtime worker.
    pub workers: Vec<WorkerOccupancyRow>,
    /// Scheduler queue depths, one row per live (model, engine) queue.
    pub queues: Vec<QueueDepthRow>,
}

/// The running serving system: the shared worker runtime plus a model
/// registry fronted by one submit surface.  Single-model deployments
/// see exactly the pre-registry behavior (one implicit model named
/// `default`).
pub struct Coordinator {
    registry: ModelRegistry,
    stats: Arc<SharedStats>,
    runtime: Runtime,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn the shared worker runtime (a fixed fleet of
    /// `cfg.workers` threads — default: detected core count), build
    /// the registry, and eagerly load the default model (fail fast on
    /// engine build errors).  Other registered models build lazily on
    /// first request unless `registry.preload` asks for all of them up
    /// front.  Model count never changes the thread count: generations
    /// only register queues.
    pub fn start(cfg: &Config) -> Result<Coordinator> {
        let stats = Arc::new(SharedStats::default());
        // A queued deadline due within ~2 batch windows preempts fair
        // share — late enough that batching still coalesces, early
        // enough that the EDF override fires before expiry.
        let urgency_window = (cfg.batch_timeout * 2).max(Duration::from_millis(20));
        let runtime = Runtime::start(
            cfg.workers,
            cfg.replica_cache_mb.saturating_mul(1 << 20),
            urgency_window,
            stats.clone(),
        );
        // Startup failures must not leak the worker fleet (tests build
        // coordinators in-process; detached idle threads add up).
        let registry = match ModelRegistry::new(cfg.clone(), stats.clone(), runtime.handle()) {
            Ok(r) => r,
            Err(e) => {
                runtime.shutdown();
                return Err(e);
            }
        };
        if let Err(e) = registry.preload(!cfg.registry.preload) {
            registry.shutdown();
            runtime.shutdown();
            return Err(e);
        }

        crate::info!(
            "coordinator",
            "ready: runtime_workers={} replica_cache={}MB models={:?} \
             default='{}' preload={}",
            runtime.workers(),
            cfg.replica_cache_mb,
            registry.names(),
            registry.default_model(),
            cfg.registry.preload
        );

        Ok(Coordinator {
            registry,
            stats,
            runtime,
            next_id: AtomicU64::new(1),
        })
    }

    /// Pin a generation of `model` (`None` = default model) for one
    /// request.  Unknown names are a structured reject; first use of a
    /// lazily-registered model builds + warms its pools here.
    pub fn lease(&self, model: Option<&str>) -> Result<GenerationLease, SubmitError> {
        self.registry.resolve(model)
    }

    /// Registered model names in registry order.
    pub fn model_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    pub fn default_model(&self) -> &str {
        self.registry.default_model()
    }

    /// Atomic hot reload of `model` (`None` = default): build + warm a
    /// fresh generation, swap it in, drain the old one in the
    /// background.  In-flight requests finish on the old generation.
    pub fn reload(&self, model: Option<&str>) -> Result<ReloadReport> {
        self.registry.reload(model)
    }

    /// Submit a best-effort image to the default model.
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_slo(image, Slo::default())
    }

    /// Submit to the default model with an SLO (owned-tensor
    /// convenience: the buffer moves into the arena's custody and is
    /// recycled on completion).
    pub fn submit_with_slo(
        &self,
        image: Tensor,
        slo: Slo,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_model(None, image, slo)
    }

    /// Submit an owned tensor to a named model (`None` = default).
    ///
    /// `Err(Closed)` can surface transiently when the addressed
    /// generation is retired by a concurrent hot reload between resolve
    /// and route; callers simply resubmit — the retry lands on the
    /// fresh generation (the TCP server reuses the already-decoded
    /// pixels via [`Coordinator::submit_on_reclaim`]).
    pub fn submit_model(
        &self,
        model: Option<&str>,
        image: Tensor,
        slo: Slo,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let lease = self.lease(model)?;
        // Validate before adopting, so rejected odd-shaped tensors are
        // never shelved into the arena's size classes.
        let want = [lease.input_hw(), lease.input_hw(), 3];
        if image.shape() != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {want:?}, got {:?}",
                image.shape()
            )));
        }
        let pooled = PooledTensor::from_tensor(image, &lease.arena());
        self.submit_on(&lease, pooled, slo, None)
    }

    /// Zero-copy submission to the default model (the image already
    /// lives in a pooled lease; the server decodes straight into one).
    pub fn submit_pooled(
        &self,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let lease = self.lease(None)?;
        self.submit_on(&lease, image, slo, wire_key)
    }

    /// Zero-copy submission onto an already-leased generation — the
    /// server's model-aware path (it needs the lease first anyway, to
    /// decode into the right arena).
    pub fn submit_on(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease.submit_pooled(id, image, slo, wire_key)
    }

    /// Like [`Coordinator::submit_on`], but on failure the decoded
    /// pixels come back with the error (when recoverable) so a
    /// reload-race `Closed` retry can resubmit the same tensor to the
    /// fresh generation without re-decoding the image.
    pub fn submit_on_reclaim(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, (SubmitError, Option<PooledTensor>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease.submit_pooled_reclaim(id, image, slo, wire_key)
    }

    /// Response-cache lookup by an externally computed key on the
    /// default model — the server's wire-key fast path (see
    /// [`crate::registry::Generation::cached_response`]).
    pub fn cached_response(&self, key: u64) -> Option<Response> {
        let lease = self.lease(None).ok()?;
        lease.cached_response(key)
    }

    /// The default model's tensor arena (decode buffers lease from here).
    pub fn pool(&self) -> TensorPool {
        match self.lease(None) {
            Ok(lease) => lease.arena(),
            // Default model is eagerly loaded at start; this arm is
            // unreachable in practice but must not panic.
            Err(_) => TensorPool::disabled(),
        }
    }

    /// Convenience: submit to the default model and wait.
    pub fn infer_blocking(&self, image: Tensor) -> Result<Response> {
        let rx = self
            .submit(image)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        rx.recv().context("worker dropped reply channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        let lat = self.stats.latency.lock().unwrap();
        let batch = self.stats.batch_sizes.lock().unwrap();
        let default = self.registry.default_model().to_string();

        let mut queued = 0usize;
        let mut cache_hits = 0u64;
        let mut cache_misses = 0u64;
        let mut shed_predicted = 0u64;
        let mut shed_expired = 0u64;
        let mut pool = PoolStats::default();
        let mut models = Vec::new();
        for entry in self.registry.entries() {
            let gen = if entry.loaded() {
                self.registry.resolve(Some(entry.name())).ok()
            } else {
                None
            };
            let (hits, misses) = match &gen {
                Some(g) => {
                    queued += g.queued();
                    let c = g.ctx().cache.stats();
                    shed_predicted += g.ctx().shed_predicted_count();
                    shed_expired += g.ctx().shed_expired_count();
                    let p = g.arena().stats();
                    pool.hits += p.hits;
                    pool.misses += p.misses;
                    pool.returned += p.returned;
                    pool.dropped += p.dropped;
                    pool.buffers += p.buffers;
                    (c.hits, c.misses)
                }
                None => (0, 0),
            };
            cache_hits += hits;
            cache_misses += misses;
            models.push(ModelStatsSnapshot {
                model: entry.name().to_string(),
                // The generation actually serving — NOT the issued
                // counter, which a failed reload bumps without ever
                // publishing (an operator must not read a reload as
                // applied when the old weights still serve).
                generation: gen.as_ref().map(|g| g.generation()).unwrap_or(0),
                loaded: gen.is_some(),
                is_default: entry.name() == default,
                completed: entry.counters().completed.load(Ordering::Relaxed),
                images: entry.counters().images.load(Ordering::Relaxed),
                rejected: entry.counters().rejected.load(Ordering::Relaxed),
                cache_hits: hits,
                cache_misses: misses,
            });
        }

        StatsSnapshot {
            completed: self.stats.completed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            images: self.stats.images.load(Ordering::Relaxed),
            queued,
            latency_summary: lat.summary(),
            mean_batch: batch.mean_ms(),
            cache_hits,
            cache_misses,
            shed_predicted,
            shed_expired,
            pool,
            models,
            workers: self.runtime.occupancy(),
            queues: self.runtime.scheduler().queue_rows(),
        }
    }

    /// Policy-layer introspection (`{"cmd":"policy"}`): the default
    /// model's pools at the top level (wire compatibility), plus one
    /// row per registered model.
    pub fn policy_snapshot(&self) -> PolicySnapshot {
        let mut models = Vec::new();
        for entry in self.registry.entries() {
            let loaded = entry.loaded();
            let gen = if loaded {
                self.registry.resolve(Some(entry.name())).ok()
            } else {
                None
            };
            models.push(match gen {
                Some(g) => ModelPolicySnapshot {
                    model: entry.name().to_string(),
                    generation: g.generation(),
                    loaded: true,
                    pools: g.pool_snapshots(),
                    cache: g.ctx().cache.stats(),
                    shed_predicted: g.ctx().shed_predicted_count(),
                    shed_expired: g.ctx().shed_expired_count(),
                },
                None => ModelPolicySnapshot {
                    model: entry.name().to_string(),
                    // No generation is serving (0) — see stats(): the
                    // issued counter would misreport failed reloads.
                    generation: 0,
                    loaded: false,
                    pools: Vec::new(),
                    cache: Default::default(),
                    shed_predicted: 0,
                    shed_expired: 0,
                },
            });
        }
        let default = self.registry.default_model();
        let default_row = models.iter().find(|m| m.model == default);
        PolicySnapshot {
            adaptive: self.registry.config().policy.adaptive,
            pools: default_row.map(|m| m.pools.clone()).unwrap_or_default(),
            cache: default_row.map(|m| m.cache).unwrap_or_default(),
            shed_predicted: models.iter().map(|m| m.shed_predicted).sum(),
            shed_expired: models.iter().map(|m| m.shed_expired).sum(),
            models,
        }
    }

    /// Latency histogram clone (bench reporting).
    pub fn latency_histogram(&self) -> Histogram {
        self.stats.latency.lock().unwrap().clone()
    }

    /// Graceful shutdown: retire every generation (close + drain its
    /// queues — including reload-retired ones still draining), then
    /// stop the shared runtime and join its fixed worker fleet.
    pub fn shutdown(self) -> Vec<WorkerReport> {
        self.registry.shutdown();
        self.runtime.shutdown()
    }
}
