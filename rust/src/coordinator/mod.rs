//! The serving coordinator — L3's composition root.
//!
//! ```text
//! submit(image, slo) ── cache ──hit──> immediate Response
//!          │
//!          ▼
//!     selector (predicted completion vs deadline, per engine pool)
//!          │                        └──none fits──> structured shed
//!     ┌────┴─────┐
//!     ▼          ▼
//!  acl pool   quant pool      (each: router -> bounded worker queues)
//!     │          │               deadline-ordered, expired shed
//!     ▼          ▼
//!  worker: engine.infer(batch) ── feeds predictor + response cache
//!          │
//!          ▼
//!  per-request Response via mpsc reply channel
//! ```
//!
//! Invariants (tested in rust/tests/coordinator_props.rs and
//! rust/tests/policy_props.rs):
//! * every admitted request gets exactly one Response (success, error,
//!   or a structured deadline rejection) — never a silent drop;
//! * rejected/shed requests are reported as rejections;
//! * FIFO within a worker queue among equal urgency;
//! * batch sizes ∈ supported artifact sizes;
//! * results are independent of batch packing;
//! * cache hits are bit-identical to the cold inference that filled them.

pub mod batcher;
pub mod queue;
pub mod router;
pub mod worker;

use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::Config;
use crate::engine::EngineKind;
use crate::metrics::Histogram;
use crate::policy::{
    self, image_key, CachedResult, Decision, PolicyCtx, PolicySnapshot,
    PoolSnapshot, PoolView, Selector, Slo,
};
use crate::runtime::Manifest;
use crate::tensor::{PoolStats, PooledTensor, Tensor, TensorPool};

use batcher::BatchPolicy;
use queue::BoundedQueue;
use router::{RouteError, Router};
use worker::{SharedStats, WorkerReport};

/// One inference request (image already preprocessed to 227x227x3,
/// living in a pooled lease so its buffer is recycled on completion).
pub struct Request {
    pub id: u64,
    pub image: PooledTensor,
    pub submitted: Instant,
    /// Deadline + priority; default is best-effort.
    pub slo: Slo,
    /// Content hash for response-cache fill (None when caching is off).
    pub cache_key: Option<u64>,
    /// Pre-decode hash of the raw image spec (None when caching is off
    /// or the spec isn't self-describing) — filled alongside
    /// `cache_key` so repeat requests skip decode entirely.
    pub wire_key: Option<u64>,
    pub reply: mpsc::Sender<Response>,
}

/// One inference response (top-k + latency breakdown).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub top1: usize,
    pub top5: Vec<(usize, f32)>,
    /// submit -> batch formed.
    pub queue_ms: f64,
    /// engine.infer wall time for the whole batch.
    pub exec_ms: f64,
    /// submit -> response.
    pub total_ms: f64,
    pub batch_size: usize,
    pub worker: usize,
    /// Which engine served this ("cache" for a cache hit, "" on error).
    pub engine: &'static str,
    /// True when served from the response cache (no inference ran).
    pub cached: bool,
    /// Machine-matchable error class ("error", "shed"; "" when ok).
    pub kind: &'static str,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: &str) -> Response {
        Response {
            id,
            top1: 0,
            top5: Vec::new(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms: 0.0,
            batch_size: 0,
            worker: usize::MAX,
            engine: "",
            cached: false,
            kind: "error",
            error: Some(msg.to_string()),
        }
    }

    /// Structured rejection for an admitted request whose deadline passed
    /// while it waited in queue (same machine-matchable kind as an
    /// admission-time shed).
    pub fn shed_expired(id: u64, msg: &str) -> Response {
        Response {
            kind: "shed",
            ..Response::error(id, msg)
        }
    }

    fn cache_hit(id: u64, hit: &CachedResult, total_ms: f64) -> Response {
        Response {
            id,
            top1: hit.top1,
            top5: hit.top5.clone(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms,
            batch_size: 0,
            worker: usize::MAX,
            engine: "cache",
            cached: true,
            kind: "",
            error: None,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Submission failure modes (backpressure + SLO surface).
#[derive(Debug, PartialEq)]
pub enum SubmitError {
    /// All worker queues full — retry later (the embedded device is saturated).
    Overloaded,
    /// No engine variant is predicted to finish inside the deadline —
    /// shed at admission instead of serving a doomed request.
    Shed {
        /// Best (smallest) margin-adjusted completion prediction, ms.
        predicted_ms: f64,
        /// The request's full deadline budget, ms.
        deadline_ms: f64,
    },
    /// Coordinator shutting down.
    Closed,
    /// Input had the wrong shape.
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "overloaded"),
            SubmitError::Shed {
                predicted_ms,
                deadline_ms,
            } => write!(
                f,
                "overloaded: predicted {predicted_ms:.0}ms exceeds \
                 deadline {deadline_ms:.0}ms on every engine"
            ),
            SubmitError::Closed => write!(f, "closed"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

/// Live stats snapshot.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub images: u64,
    pub queued: usize,
    pub latency_summary: (f64, f64, f64, f64, f64),
    pub mean_batch: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Requests shed at admission by the SLO selector.
    pub shed_predicted: u64,
    /// Admitted requests shed in-queue after their deadline passed.
    pub shed_expired: u64,
    /// Tensor-arena counters (hit/miss/returned/dropped/buffers).
    pub pool: PoolStats,
}

/// One engine pool: a router over per-worker bounded queues.
struct Pool {
    kind: EngineKind,
    router: Router<Request>,
    workers: usize,
}

impl Pool {
    /// Admission-time snapshot for the selector / introspection.
    fn view(&self) -> PoolView {
        PoolView {
            kind: self.kind,
            queued: self.router.queued(),
            workers: self.workers,
            capacity: self.router.capacity(),
        }
    }
}

/// The running serving system.
pub struct Coordinator {
    pools: Vec<Pool>,
    worker_handles: Vec<std::thread::JoinHandle<WorkerReport>>,
    selector: Selector,
    ctx: Arc<PolicyCtx>,
    adaptive: bool,
    next_id: AtomicU64,
    stats: Arc<SharedStats>,
    input_hw: usize,
    pool: TensorPool,
}

/// Batch sizes a given engine kind has compiled artifacts for.
fn supported_sizes(kind: EngineKind, manifest: &Manifest) -> Vec<usize> {
    match kind {
        EngineKind::AclStaged => manifest.batch_sizes.clone(),
        EngineKind::AclFused => manifest.full.keys().copied().collect(),
        _ => vec![1],
    }
}

impl Coordinator {
    /// Load manifest, spawn + warm all worker pools.  Returns only when
    /// every worker is ready to serve (compilation excluded from request
    /// latency) — or fails fast if any worker can't build its engine.
    ///
    /// With `cfg.policy.adaptive`, two pools come up — the configured
    /// engine (quality path) plus the int8 quant path — and the SLO
    /// selector routes between them per request.
    pub fn start(cfg: &Config) -> Result<Coordinator> {
        let manifest = Manifest::load(&cfg.artifacts).context("loading manifest")?;

        let specs: Vec<(EngineKind, usize)> = if cfg.policy.adaptive {
            vec![
                (cfg.engine, cfg.workers),
                (EngineKind::Quant, cfg.policy.quant_workers),
            ]
        } else {
            vec![(cfg.engine, cfg.workers)]
        };

        let ctx = Arc::new(PolicyCtx::new(
            cfg.policy.ewma_alpha,
            cfg.policy.cache_capacity,
        ));
        for &(kind, _) in &specs {
            ctx.predictor.seed(kind, 1, policy::default_prior_ms(kind));
        }

        let stats = Arc::new(SharedStats::default());
        let (ready_tx, ready_rx) = mpsc::channel();

        // Tensor arena for the whole request path: decode buffers plus
        // one batch buffer per compiled batch size, shelved at startup
        // so the steady state never allocates pixels.
        let input_len = manifest.input_hw * manifest.input_hw * 3;
        let arena = TensorPool::with_mode(cfg.pool.enabled, cfg.pool.per_class_cap);
        arena.prealloc(input_len, cfg.queue_capacity);

        let mut pools = Vec::with_capacity(specs.len());
        let mut worker_handles = Vec::new();
        let mut worker_index = 0usize;
        for (pool_index, &(kind, n_workers)) in specs.iter().enumerate() {
            let supported = supported_sizes(kind, &manifest);
            for &b in supported.iter().filter(|&&b| b <= cfg.max_batch) {
                arena.prealloc(b * input_len, n_workers);
            }
            let policy = BatchPolicy::new(cfg.max_batch, cfg.batch_timeout, &supported);
            let queues: Vec<Arc<BoundedQueue<Request>>> = (0..n_workers)
                .map(|_| Arc::new(BoundedQueue::new(cfg.queue_capacity)))
                .collect();
            for q in &queues {
                worker_handles.push(worker::spawn_worker(
                    worker_index,
                    kind,
                    manifest.clone(),
                    q.clone(),
                    policy.clone(),
                    stats.clone(),
                    ctx.clone(),
                    arena.clone(),
                    // Only the quality pool (specs[0]) fills the cache so
                    // hits never downgrade accuracy to the int8 path.
                    pool_index == 0,
                    ready_tx.clone(),
                ));
                worker_index += 1;
            }
            pools.push(Pool {
                kind,
                router: Router::new(queues),
                workers: n_workers,
            });
        }
        drop(ready_tx);

        // Wait for all workers (fail fast on any engine build error).
        for _ in 0..worker_index {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    for p in &pools {
                        p.router.close_all();
                    }
                    bail!("worker failed to start: {e:#}");
                }
                Err(_) => bail!("worker exited before signalling readiness"),
            }
        }

        crate::info!(
            "coordinator",
            "ready: pools={:?} max_batch={} adaptive={} cache={}",
            pools
                .iter()
                .map(|p| format!("{}x{}", p.kind.as_str(), p.workers))
                .collect::<Vec<_>>(),
            cfg.max_batch,
            cfg.policy.adaptive,
            cfg.policy.cache_capacity
        );

        Ok(Coordinator {
            pools,
            worker_handles,
            selector: Selector::new(cfg.policy.margin, 1),
            ctx,
            adaptive: cfg.policy.adaptive,
            next_id: AtomicU64::new(1),
            stats,
            input_hw: manifest.input_hw,
            pool: arena,
        })
    }

    /// Submit a best-effort image; returns the reply channel.
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_slo(image, Slo::default())
    }

    /// Reject wrong-shaped inputs before they touch queues or the arena.
    fn check_shape(&self, shape: &[usize]) -> Result<(), SubmitError> {
        let want = [self.input_hw, self.input_hw, 3];
        if shape != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {want:?}, got {shape:?}"
            )));
        }
        Ok(())
    }

    /// Submit with an SLO (owned-tensor convenience: the buffer moves
    /// into the arena's custody and is recycled on completion).
    pub fn submit_with_slo(
        &self,
        image: Tensor,
        slo: Slo,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        // Validate before adopting, so rejected odd-shaped tensors are
        // never shelved into the arena's size classes.
        self.check_shape(image.shape())?;
        let image = PooledTensor::from_tensor(image, &self.pool);
        self.submit_pooled(image, slo, None)
    }

    /// Zero-copy submission: the image already lives in a pooled lease
    /// (the server decodes straight into one).  The cache is consulted
    /// first (a hit replies immediately without touching an engine);
    /// otherwise the selector routes to the best pool predicted to meet
    /// the deadline, or sheds.  `wire_key` optionally keys the response
    /// cache on the raw request bytes so a repeat of the same wire spec
    /// skips decode entirely next time.
    pub fn submit_pooled(
        &self,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.check_shape(image.shape())?;
        let submitted = Instant::now();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);

        // Response cache: repeated frames skip inference entirely.
        let cache_key = if self.ctx.cache.enabled() {
            let key = image_key(image.data());
            if let Some(hit) = self.ctx.cache.get(key) {
                // Re-install the wire-key alias: it may have been
                // LRU-evicted independently of the content entry, and
                // this request never reaches a worker to restore it.
                if let Some(wk) = wire_key {
                    self.ctx.cache.put(wk, hit.clone());
                }
                let (tx, rx) = mpsc::channel();
                let total_ms = crate::util::ms(submitted.elapsed());
                let _ = tx.send(Response::cache_hit(id, &hit, total_ms));
                self.stats.completed.fetch_add(1, Ordering::Relaxed);
                self.stats.latency.lock().unwrap().record_ms(total_ms);
                return Ok(rx);
            }
            Some(key)
        } else {
            None
        };

        let views: Vec<PoolView> = self.pools.iter().map(Pool::view).collect();
        let budget_ms = slo.deadline_ms();
        let decision =
            self.selector
                .choose(&self.ctx.predictor, &views, &slo, budget_ms);

        let pool = match decision {
            Decision::Route { pool, .. } => pool,
            Decision::Shed { best_ms } => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                let any_room = views.iter().any(|v| v.queued < v.capacity);
                return Err(match (budget_ms, any_room) {
                    (Some(deadline_ms), true) => {
                        self.ctx.shed_predicted.fetch_add(1, Ordering::Relaxed);
                        SubmitError::Shed {
                            predicted_ms: best_ms,
                            deadline_ms,
                        }
                    }
                    _ => SubmitError::Overloaded,
                });
            }
        };

        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            image,
            submitted,
            slo,
            cache_key,
            wire_key: wire_key.filter(|_| cache_key.is_some()),
            reply: tx,
        };
        match self.pools[pool].router.route(req) {
            Ok(_) => Ok(rx),
            Err(RouteError::Overloaded(_)) => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(RouteError::Closed(_)) => Err(SubmitError::Closed),
        }
    }

    /// Response-cache lookup by an externally computed key — the
    /// server's wire-key fast path.  A hit means the caller can skip
    /// image decode entirely; a miss is not counted against the cache
    /// (the post-decode content-key lookup counts once per request).
    pub fn cached_response(&self, key: u64) -> Option<Response> {
        if !self.ctx.cache.enabled() {
            return None;
        }
        let t0 = Instant::now();
        let hit = self.ctx.cache.peek(key)?;
        // Measured, like the content-key hit path — cache hits are real
        // requests with (near-zero) real latency.
        let total_ms = crate::util::ms(t0.elapsed());
        let resp = Response::cache_hit(0, &hit, total_ms);
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.latency.lock().unwrap().record_ms(total_ms);
        Some(resp)
    }

    /// The request path's tensor arena (decode buffers lease from here).
    pub fn pool(&self) -> TensorPool {
        self.pool.clone()
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, image: Tensor) -> Result<Response> {
        let rx = self
            .submit(image)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        rx.recv().context("worker dropped reply channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        let lat = self.stats.latency.lock().unwrap();
        let batch = self.stats.batch_sizes.lock().unwrap();
        let cache = self.ctx.cache.stats();
        StatsSnapshot {
            completed: self.stats.completed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            images: self.stats.images.load(Ordering::Relaxed),
            queued: self.pools.iter().map(|p| p.router.queued()).sum(),
            latency_summary: lat.summary(),
            mean_batch: batch.mean_ms(),
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            shed_predicted: self.ctx.shed_predicted_count(),
            shed_expired: self.ctx.shed_expired_count(),
            pool: self.pool.stats(),
        }
    }

    /// Policy-layer introspection (`{"cmd":"policy"}`).
    pub fn policy_snapshot(&self) -> PolicySnapshot {
        PolicySnapshot {
            adaptive: self.adaptive,
            pools: self
                .pools
                .iter()
                .map(|p| {
                    let view = p.view();
                    PoolSnapshot {
                        engine: p.kind.as_str(),
                        workers: p.workers,
                        queued: view.queued,
                        capacity: view.capacity,
                        predicted_ms: self
                            .selector
                            .predict_ms(&self.ctx.predictor, &view),
                        samples: self.ctx.predictor.samples(p.kind),
                    }
                })
                .collect(),
            cache: self.ctx.cache.stats(),
            shed_predicted: self.ctx.shed_predicted_count(),
            shed_expired: self.ctx.shed_expired_count(),
        }
    }

    /// Latency histogram clone (bench reporting).
    pub fn latency_histogram(&self) -> Histogram {
        self.stats.latency.lock().unwrap().clone()
    }

    /// Graceful shutdown: drain queues, join workers, return their reports.
    pub fn shutdown(self) -> Vec<WorkerReport> {
        for p in &self.pools {
            p.router.close_all();
        }
        self.worker_handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}
