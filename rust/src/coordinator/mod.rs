//! The serving coordinator — L3's composition root.
//!
//! ```text
//! submit(model?, image, slo)
//!          │
//!          ▼
//!   registry.resolve(model) ──unknown──> structured UnknownModel reject
//!          │                             (never a default-model fallback)
//!          ▼  GenerationLease (RAII pin on one model generation)
//!     per-model cache ──hit──> immediate Response
//!          │
//!          ▼
//!     selector (predicted completion vs deadline, per engine queue)
//!          │                        └──none fits──> structured shed
//!     ┌────┴─────┐
//!     ▼          ▼
//!  acl queue  quant queue      (one bounded queue per (model, engine)
//!     │          │              generation, registered with the
//!     └────┬─────┘              process-wide scheduler)
//!          ▼
//!  shared worker runtime: a FIXED fleet of threads (default = core
//!  count) pulls the next queue by deadline urgency then weighted fair
//!  share, executes the batch on an LRU-cached engine replica, feeds
//!  the generation's predictor + response cache
//!          │
//!          ▼
//!  per-request Response (carries the model name) via mpsc reply channel
//! ```
//!
//! Invariants (tested in rust/tests/coordinator_props.rs,
//! rust/tests/policy_props.rs, rust/tests/registry_props.rs, and
//! rust/tests/scheduler_props.rs):
//! * every admitted request gets exactly one Response (success, error,
//!   or a structured deadline rejection) — never a silent drop;
//! * rejected/shed requests are reported as rejections;
//! * FIFO within a queue among equal urgency;
//! * batch sizes ∈ supported artifact sizes;
//! * results are independent of batch packing;
//! * cache hits are bit-identical to the cold inference that filled them;
//! * cache hits never cross models or weight generations;
//! * a hot reload never drops an in-flight request (old generation
//!   drains before its pooled tensors / worker replicas are released);
//! * total worker threads equal the configured runtime size regardless
//!   of model count or concurrent reloads, and a saturating hot model
//!   cannot starve a cold model's deadlined requests.

pub mod batcher;
pub mod queue;
pub mod router;
pub mod scheduler;
pub mod worker;

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::metrics::Histogram;
use crate::obs::{ObsCounters, ObsHub, Span, StageRow, STAGES};
use crate::policy::{CachedResult, ModelPolicySnapshot, PolicySnapshot, Slo};
use crate::registry::{GenerationLease, ModelRegistry, ReloadReport};
use crate::tensor::{PoolStats, PooledTensor, Tensor, TensorPool};

use scheduler::{QueueDepthRow, Runtime, WorkerOccupancyRow};
use worker::{SharedStats, WorkerReport};

/// One inference request (image already preprocessed, living in a
/// pooled lease so its buffer is recycled on completion).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub image: PooledTensor,
    pub submitted: Instant,
    /// Deadline + priority; default is best-effort.
    pub slo: Slo,
    /// Content hash for response-cache fill (None when caching is off).
    pub cache_key: Option<u64>,
    /// Pre-decode hash of the raw image spec (None when caching is off
    /// or the spec isn't self-describing) — filled alongside
    /// `cache_key` so repeat requests skip decode entirely.
    pub wire_key: Option<u64>,
    pub reply: ReplySink,
    /// Lifecycle timeline (DESIGN.md §10): stage marks stamped as the
    /// request crosses the planes, carried inline so stamping never
    /// locks or allocates.
    pub span: Span,
}

/// Routing key for an async completion: which connection to wake and
/// which *client-assigned* request id to stamp on the response line
/// (the coordinator's internal ids never reach the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompletionToken {
    pub conn: u64,
    pub request: u64,
}

/// Where async completions land.  The event-driven server implements
/// this over its per-IO-thread completion queue + eventfd wake; the
/// coordinator only ever sees the trait, so the dependency points
/// server -> coordinator, never back.
///
/// `complete` is called from runtime worker threads (and from the
/// submit path on cache hits) — implementations must be cheap and
/// never block on the IO loop they wake.
pub trait CompletionSink: Send + Sync {
    fn complete(&self, token: CompletionToken, resp: Response);
}

enum SinkInner {
    /// Synchronous callers: the classic per-request mpsc channel
    /// (`rx.recv()` blocks the calling thread — library surface,
    /// examples, benches, the threads-plane server).
    Channel(mpsc::Sender<Response>),
    /// Asynchronous callers: the response is pushed to a completion
    /// queue keyed by (connection, client request id) and the IO loop
    /// is woken — one connection can have many requests in flight.
    Completion {
        sink: Arc<dyn CompletionSink>,
        token: CompletionToken,
    },
}

/// Exactly-one-reply carrier for an admitted request.
///
/// The channel variant inherits mpsc semantics: dropping the sender
/// unsent makes `rx.recv()` fail, which callers already surface as
/// "worker gone".  The completion variant has no receiver to observe a
/// drop, so `Drop` delivers a structured error completion instead —
/// an admitted async request can never vanish silently, even if a
/// queue is torn down with requests still inside.
pub struct ReplySink {
    inner: SinkInner,
    sent: std::sync::atomic::AtomicBool,
}

impl ReplySink {
    pub fn channel(tx: mpsc::Sender<Response>) -> ReplySink {
        ReplySink {
            inner: SinkInner::Channel(tx),
            sent: std::sync::atomic::AtomicBool::new(false),
        }
    }

    pub fn completion(sink: Arc<dyn CompletionSink>, token: CompletionToken) -> ReplySink {
        ReplySink {
            inner: SinkInner::Completion { sink, token },
            sent: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Suppress the drop backstop without delivering anything — used on
    /// admission-failure paths where the caller still owns the error
    /// and reports it itself (a backstop completion here would be a
    /// double reply).
    pub fn disarm(&self) {
        self.sent.store(true, Ordering::Release);
    }

    /// Deliver the response (first call wins; later calls are dropped
    /// so a double-send bug can never double-complete a connection).
    pub fn send(&self, resp: Response) {
        if self.sent.swap(true, Ordering::AcqRel) {
            return;
        }
        match &self.inner {
            SinkInner::Channel(tx) => {
                let _ = tx.send(resp);
            }
            SinkInner::Completion { sink, token } => sink.complete(*token, resp),
        }
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if self.sent.load(Ordering::Acquire) {
            return;
        }
        if let SinkInner::Completion { sink, token } = &self.inner {
            // Mirror the channel variant's "worker gone" recv error.
            sink.complete(*token, Response::error(token.request, "worker gone"));
        }
    }
}

impl std::fmt::Debug for ReplySink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            SinkInner::Channel(_) => write!(f, "ReplySink::Channel"),
            SinkInner::Completion { token, .. } => {
                write!(f, "ReplySink::Completion({token:?})")
            }
        }
    }
}

/// One inference response (top-k + latency breakdown).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub top1: usize,
    pub top5: Vec<(usize, f32)>,
    /// submit -> batch formed.
    pub queue_ms: f64,
    /// engine.infer wall time for the whole batch.
    pub exec_ms: f64,
    /// submit -> response.
    pub total_ms: f64,
    pub batch_size: usize,
    pub worker: usize,
    /// Which engine served this ("cache" for a cache hit, "" on error).
    pub engine: &'static str,
    /// Which registry model served this ("" on pre-resolution errors).
    pub model: Arc<str>,
    /// True when served from the response cache (no inference ran).
    pub cached: bool,
    /// Machine-matchable error class ("error", "shed"; "" when ok).
    pub kind: &'static str,
    pub error: Option<String>,
    /// The request's lifecycle timeline, carried back so the connection
    /// plane can stamp `reply_flushed` and hand the finished span to
    /// the hub.  `None` on pre-admission errors (nothing was traced).
    pub span: Option<Span>,
}

impl Response {
    pub fn error(id: u64, msg: &str) -> Response {
        Response {
            id,
            top1: 0,
            top5: Vec::new(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms: 0.0,
            batch_size: 0,
            worker: usize::MAX,
            engine: "",
            model: Arc::from(""),
            cached: false,
            kind: "error",
            error: Some(msg.to_string()),
            span: None,
        }
    }

    /// Structured rejection for an admitted request whose deadline passed
    /// while it waited in queue (same machine-matchable kind as an
    /// admission-time shed).
    pub fn shed_expired(id: u64, msg: &str) -> Response {
        Response {
            kind: "shed",
            ..Response::error(id, msg)
        }
    }

    pub(crate) fn cache_hit(id: u64, hit: &CachedResult, total_ms: f64) -> Response {
        Response {
            id,
            top1: hit.top1,
            top5: hit.top5.clone(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms,
            batch_size: 0,
            worker: usize::MAX,
            engine: "cache",
            model: Arc::from(""),
            cached: true,
            kind: "",
            error: None,
            span: None,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Submission failure modes (backpressure + SLO + registry surface).
#[derive(Debug, PartialEq)]
pub enum SubmitError {
    /// All worker queues full — retry later (the embedded device is saturated).
    Overloaded,
    /// No engine variant is predicted to finish inside the deadline —
    /// shed at admission instead of serving a doomed request.
    Shed {
        /// Best (smallest) margin-adjusted completion prediction, ms.
        predicted_ms: f64,
        /// The request's full deadline budget, ms.
        deadline_ms: f64,
    },
    /// Coordinator shutting down (or the addressed generation was
    /// retired mid-swap — callers may re-resolve and retry once).
    Closed,
    /// Input had the wrong shape.
    BadInput(String),
    /// The request addressed a model the registry does not know.  A
    /// structured reject — never a silent fallback to the default model.
    UnknownModel(String),
    /// The model is registered but its generation could not be built
    /// (bad artifacts, engine build failure).
    ModelUnavailable { model: String, reason: String },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "overloaded"),
            SubmitError::Shed {
                predicted_ms,
                deadline_ms,
            } => write!(
                f,
                "overloaded: predicted {predicted_ms:.0}ms exceeds \
                 deadline {deadline_ms:.0}ms on every engine"
            ),
            SubmitError::Closed => write!(f, "closed"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
            SubmitError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            SubmitError::ModelUnavailable { model, reason } => {
                write!(f, "model '{model}' unavailable: {reason}")
            }
        }
    }
}

/// Per-model row in a [`StatsSnapshot`].
#[derive(Debug, Clone)]
pub struct ModelStatsSnapshot {
    pub model: String,
    /// Generation currently serving (0 = none; a failed reload never
    /// shows up here — only published generations count).
    pub generation: u64,
    /// Whether engine pools are currently built for this model.
    pub loaded: bool,
    /// Whether this is the default model (serves `model`-less requests).
    pub is_default: bool,
    pub completed: u64,
    pub images: u64,
    pub rejected: u64,
    /// Current generation's response-cache hits/misses (0 when unloaded;
    /// resets on reload — new weights mean a cold cache).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Probe build + warm-up wall time of the serving generation, ms
    /// (0.0 when unloaded, or when a no-op reload skipped the rebuild).
    pub warm_ms: f64,
    /// AOT snapshot counters (survive reloads; DESIGN.md §11):
    /// replica builds served from a snapshot / cold builds that found
    /// no usable snapshot / validated snapshots whose engine build
    /// failed and fell back cold.
    pub snapshot_hits: u64,
    pub snapshot_misses: u64,
    pub snapshot_fallbacks: u64,
    /// Replicas pre-built by the predictive warm-up path.
    pub prefetch_builds: u64,
}

/// Live stats snapshot.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub images: u64,
    pub queued: usize,
    pub latency_summary: (f64, f64, f64, f64, f64),
    pub mean_batch: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Requests shed at admission by the SLO selector.
    pub shed_predicted: u64,
    /// Admitted requests shed in-queue after their deadline passed.
    pub shed_expired: u64,
    /// Tensor-arena counters (hit/miss/returned/dropped/buffers),
    /// summed across loaded model generations.
    pub pool: PoolStats,
    /// Per-model breakdown, in registry order.
    pub models: Vec<ModelStatsSnapshot>,
    /// Shared-runtime worker occupancy, one row per runtime worker.
    pub workers: Vec<WorkerOccupancyRow>,
    /// Scheduler queue depths, one row per live (model, engine) queue.
    pub queues: Vec<QueueDepthRow>,
}

/// Per-model stage-latency rows in a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct ModelStageRows {
    pub model: String,
    pub stages: Vec<StageRow>,
}

/// The `{"cmd":"metrics"}` payload: every subsystem's counters in one
/// snapshot — the full [`StatsSnapshot`] (scheduler queues, workers,
/// caches, pools, shed counters) plus the per-stage latency breakdown
/// (merged across models via [`Histogram::merge`], and per model) and
/// the tracing hub's own counters.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub stats: StatsSnapshot,
    /// Stage-latency rows merged across every loaded model.
    pub stages: Vec<StageRow>,
    /// Per-model stage-latency rows, in registry order.
    pub model_stages: Vec<ModelStageRows>,
    /// Tracing-plane counters (sampling, rings, anomalies).
    pub obs: ObsCounters,
}

/// The running serving system: the shared worker runtime plus a model
/// registry fronted by one submit surface.  Single-model deployments
/// see exactly the pre-registry behavior (one implicit model named
/// `default`).
pub struct Coordinator {
    registry: ModelRegistry,
    stats: Arc<SharedStats>,
    runtime: Runtime,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn the shared worker runtime (a fixed fleet of
    /// `cfg.workers` threads — default: detected core count), build
    /// the registry, and eagerly load the default model (fail fast on
    /// engine build errors).  Other registered models build lazily on
    /// first request unless `registry.preload` asks for all of them up
    /// front.  Model count never changes the thread count: generations
    /// only register queues.
    pub fn start(cfg: &Config) -> Result<Coordinator> {
        let stats = Arc::new(SharedStats {
            // One trace ring per runtime worker plus one per IO lane:
            // every completion path writes to "its" ring without
            // contending with the others.
            obs: Arc::new(ObsHub::new(
                cfg.obs.trace_sample_rate,
                cfg.obs.trace_ring,
                cfg.obs.slow_log,
                cfg.workers + cfg.server.io_threads,
            )),
            ..SharedStats::default()
        });
        // A queued deadline due within ~2 batch windows preempts fair
        // share — late enough that batching still coalesces, early
        // enough that the EDF override fires before expiry.
        let urgency_window = (cfg.batch_timeout * 2).max(Duration::from_millis(20));
        let runtime = Runtime::start(
            cfg.workers,
            cfg.replica_cache_mb.saturating_mul(1 << 20),
            urgency_window,
            stats.clone(),
        );
        // Predictive warm-up (DESIGN.md §11): idle workers pre-build
        // replicas for queues whose arrival EWMA crosses the threshold,
        // at most once per worker per queue (each worker has its own
        // byte-bounded replica cache).  0.0 (the default) disables it.
        runtime
            .scheduler()
            .set_prefetch(cfg.prefetch_threshold, runtime.workers());
        // Startup failures must not leak the worker fleet (tests build
        // coordinators in-process; detached idle threads add up).
        let registry = match ModelRegistry::new(cfg.clone(), stats.clone(), runtime.handle()) {
            Ok(r) => r,
            Err(e) => {
                runtime.shutdown();
                return Err(e);
            }
        };
        if let Err(e) = registry.preload(!cfg.registry.preload) {
            registry.shutdown();
            runtime.shutdown();
            return Err(e);
        }

        crate::info!(
            "coordinator",
            "ready: runtime_workers={} replica_cache={}MB models={:?} \
             default='{}' preload={}",
            runtime.workers(),
            cfg.replica_cache_mb,
            registry.names(),
            registry.default_model(),
            cfg.registry.preload
        );

        Ok(Coordinator {
            registry,
            stats,
            runtime,
            next_id: AtomicU64::new(1),
        })
    }

    /// Pin a generation of `model` (`None` = default model) for one
    /// request.  Unknown names are a structured reject; first use of a
    /// lazily-registered model builds + warms its pools here.
    pub fn lease(&self, model: Option<&str>) -> Result<GenerationLease, SubmitError> {
        self.registry.resolve(model)
    }

    /// Registered model names in registry order.
    pub fn model_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    pub fn default_model(&self) -> &str {
        self.registry.default_model()
    }

    /// Atomic hot reload of `model` (`None` = default): build + warm a
    /// fresh generation, swap it in, drain the old one in the
    /// background.  In-flight requests finish on the old generation.
    pub fn reload(&self, model: Option<&str>) -> Result<ReloadReport> {
        self.registry.reload(model)
    }

    /// Submit a best-effort image to the default model.
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_slo(image, Slo::default())
    }

    /// Submit to the default model with an SLO (owned-tensor
    /// convenience: the buffer moves into the arena's custody and is
    /// recycled on completion).
    pub fn submit_with_slo(
        &self,
        image: Tensor,
        slo: Slo,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_model(None, image, slo)
    }

    /// Submit an owned tensor to a named model (`None` = default).
    ///
    /// `Err(Closed)` can surface transiently when the addressed
    /// generation is retired by a concurrent hot reload between resolve
    /// and route; callers simply resubmit — the retry lands on the
    /// fresh generation (the TCP server reuses the already-decoded
    /// pixels via [`Coordinator::submit_on_reclaim`]).
    pub fn submit_model(
        &self,
        model: Option<&str>,
        image: Tensor,
        slo: Slo,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let lease = self.lease(model)?;
        // Validate before adopting, so rejected odd-shaped tensors are
        // never shelved into the arena's size classes.
        let want = [lease.input_hw(), lease.input_hw(), 3];
        if image.shape() != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {want:?}, got {:?}",
                image.shape()
            )));
        }
        let pooled = PooledTensor::from_tensor(image, &lease.arena());
        self.submit_on(&lease, pooled, slo, None)
    }

    /// Zero-copy submission to the default model (the image already
    /// lives in a pooled lease; the server decodes straight into one).
    pub fn submit_pooled(
        &self,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let lease = self.lease(None)?;
        self.submit_on(&lease, image, slo, wire_key)
    }

    /// Zero-copy submission onto an already-leased generation — the
    /// server's model-aware path (it needs the lease first anyway, to
    /// decode into the right arena).
    pub fn submit_on(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease.submit_pooled(id, image, slo, wire_key)
    }

    /// Like [`Coordinator::submit_on`], but on failure the decoded
    /// pixels come back with the error (when recoverable) so a
    /// reload-race `Closed` retry can resubmit the same tensor to the
    /// fresh generation without re-decoding the image.
    pub fn submit_on_reclaim(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, (SubmitError, Option<PooledTensor>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease.submit_pooled_reclaim(id, image, slo, wire_key)
    }

    /// Asynchronous submission: instead of handing back a receiver to
    /// block on, the eventual [`Response`] is delivered through `reply`
    /// (a [`ReplySink`], usually the event-driven server's completion
    /// queue).  `Ok(())` guarantees exactly one delivery — immediately
    /// for a cache hit, from a runtime worker otherwise, and from the
    /// sink's drop backstop if the request is torn down mid-flight.
    /// `Err` means nothing was delivered; recoverable errors hand the
    /// decoded pixels back for a reload-race retry, exactly like
    /// [`Coordinator::submit_on_reclaim`].
    pub fn submit_on_sink(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
        reply: ReplySink,
    ) -> Result<(), (SubmitError, Option<PooledTensor>)> {
        let span = self.stats.obs.begin();
        self.submit_on_sink_traced(lease, image, slo, wire_key, reply, span)
    }

    /// [`Coordinator::submit_on_sink`] with a caller-begun [`Span`] —
    /// the server planes stamp `accepted`/`parsed` at the socket before
    /// submitting, so the timeline covers the connection plane too.
    pub fn submit_on_sink_traced(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
        reply: ReplySink,
        span: Span,
    ) -> Result<(), (SubmitError, Option<PooledTensor>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease.submit_sink_traced(id, image, slo, wire_key, reply, span)
    }

    /// [`Coordinator::submit_on_reclaim`] with a caller-begun [`Span`]
    /// (the threads plane's traced path).
    pub fn submit_on_reclaim_traced(
        &self,
        lease: &GenerationLease,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
        span: Span,
    ) -> Result<mpsc::Receiver<Response>, (SubmitError, Option<PooledTensor>)> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lease
            .submit_sink_traced(id, image, slo, wire_key, ReplySink::channel(tx), span)
            .map(|()| rx)
    }

    /// Response-cache lookup by an externally computed key on the
    /// default model — the server's wire-key fast path (see
    /// [`crate::registry::Generation::cached_response`]).
    pub fn cached_response(&self, key: u64) -> Option<Response> {
        let lease = self.lease(None).ok()?;
        lease.cached_response(key)
    }

    /// The default model's tensor arena (decode buffers lease from here).
    pub fn pool(&self) -> TensorPool {
        match self.lease(None) {
            Ok(lease) => lease.arena(),
            // Default model is eagerly loaded at start; this arm is
            // unreachable in practice but must not panic.
            Err(_) => TensorPool::disabled(),
        }
    }

    /// Convenience: submit to the default model and wait.
    pub fn infer_blocking(&self, image: Tensor) -> Result<Response> {
        let rx = self
            .submit(image)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        rx.recv().context("worker dropped reply channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        let lat = self.stats.latency.lock().unwrap();
        let batch = self.stats.batch_sizes.lock().unwrap();
        let default = self.registry.default_model().to_string();

        let mut queued = 0usize;
        let mut cache_hits = 0u64;
        let mut cache_misses = 0u64;
        let mut shed_predicted = 0u64;
        let mut shed_expired = 0u64;
        let mut pool = PoolStats::default();
        let mut models = Vec::new();
        for entry in self.registry.entries() {
            let gen = if entry.loaded() {
                self.registry.resolve(Some(entry.name())).ok()
            } else {
                None
            };
            let (hits, misses) = match &gen {
                Some(g) => {
                    queued += g.queued();
                    let c = g.ctx().cache.stats();
                    shed_predicted += g.ctx().shed_predicted_count();
                    shed_expired += g.ctx().shed_expired_count();
                    let p = g.arena().stats();
                    pool.hits += p.hits;
                    pool.misses += p.misses;
                    pool.returned += p.returned;
                    pool.dropped += p.dropped;
                    pool.buffers += p.buffers;
                    (c.hits, c.misses)
                }
                None => (0, 0),
            };
            cache_hits += hits;
            cache_misses += misses;
            models.push(ModelStatsSnapshot {
                model: entry.name().to_string(),
                // The generation actually serving — NOT the issued
                // counter, which a failed reload bumps without ever
                // publishing (an operator must not read a reload as
                // applied when the old weights still serve).
                generation: gen.as_ref().map(|g| g.generation()).unwrap_or(0),
                loaded: gen.is_some(),
                is_default: entry.name() == default,
                completed: entry.counters().completed.load(Ordering::Relaxed),
                images: entry.counters().images.load(Ordering::Relaxed),
                rejected: entry.counters().rejected.load(Ordering::Relaxed),
                cache_hits: hits,
                cache_misses: misses,
                warm_ms: gen.as_ref().map(|g| g.warm_ms()).unwrap_or(0.0),
                snapshot_hits: entry.counters().snapshot_hits.load(Ordering::Relaxed),
                snapshot_misses: entry
                    .counters()
                    .snapshot_misses
                    .load(Ordering::Relaxed),
                snapshot_fallbacks: entry
                    .counters()
                    .snapshot_fallbacks
                    .load(Ordering::Relaxed),
                prefetch_builds: entry
                    .counters()
                    .prefetch_builds
                    .load(Ordering::Relaxed),
            });
        }

        StatsSnapshot {
            completed: self.stats.completed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            images: self.stats.images.load(Ordering::Relaxed),
            queued,
            latency_summary: lat.summary(),
            mean_batch: batch.mean_ms(),
            cache_hits,
            cache_misses,
            shed_predicted,
            shed_expired,
            pool,
            models,
            workers: self.runtime.occupancy(),
            queues: self.runtime.scheduler().queue_rows(),
        }
    }

    /// Policy-layer introspection (`{"cmd":"policy"}`): the default
    /// model's pools at the top level (wire compatibility), plus one
    /// row per registered model.
    pub fn policy_snapshot(&self) -> PolicySnapshot {
        let mut models = Vec::new();
        for entry in self.registry.entries() {
            let loaded = entry.loaded();
            let gen = if loaded {
                self.registry.resolve(Some(entry.name())).ok()
            } else {
                None
            };
            models.push(match gen {
                Some(g) => ModelPolicySnapshot {
                    model: entry.name().to_string(),
                    generation: g.generation(),
                    loaded: true,
                    pools: g.pool_snapshots(),
                    cache: g.ctx().cache.stats(),
                    shed_predicted: g.ctx().shed_predicted_count(),
                    shed_expired: g.ctx().shed_expired_count(),
                },
                None => ModelPolicySnapshot {
                    model: entry.name().to_string(),
                    // No generation is serving (0) — see stats(): the
                    // issued counter would misreport failed reloads.
                    generation: 0,
                    loaded: false,
                    pools: Vec::new(),
                    cache: Default::default(),
                    shed_predicted: 0,
                    shed_expired: 0,
                },
            });
        }
        let default = self.registry.default_model();
        let default_row = models.iter().find(|m| m.model == default);
        PolicySnapshot {
            adaptive: self.registry.config().policy.adaptive,
            pools: default_row.map(|m| m.pools.clone()).unwrap_or_default(),
            cache: default_row.map(|m| m.cache).unwrap_or_default(),
            shed_predicted: models.iter().map(|m| m.shed_predicted).sum(),
            shed_expired: models.iter().map(|m| m.shed_expired).sum(),
            models,
        }
    }

    /// Latency histogram clone (bench reporting).
    pub fn latency_histogram(&self) -> Histogram {
        self.stats.latency.lock().unwrap().clone()
    }

    /// The tracing hub (span epoch, rings, slow log) — the server
    /// planes begin and complete spans through this.
    pub fn obs(&self) -> &Arc<ObsHub> {
        &self.stats.obs
    }

    /// The unified metrics snapshot behind `{"cmd":"metrics"}`: the
    /// full stats snapshot plus per-stage latency histograms (merged
    /// across loaded models via [`Histogram::merge`]) and tracing
    /// counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        let stats = self.stats();
        let mut merged: Vec<Histogram> =
            (0..STAGES).map(|_| Histogram::with_cap(4096)).collect();
        let mut model_stages = Vec::new();
        for entry in self.registry.entries() {
            if !entry.loaded() {
                continue;
            }
            let Ok(g) = self.registry.resolve(Some(entry.name())) else {
                continue;
            };
            let hists = g.stage_histograms();
            for (acc, h) in merged.iter_mut().zip(hists.iter()) {
                acc.merge(h);
            }
            model_stages.push(ModelStageRows {
                model: entry.name().to_string(),
                stages: crate::obs::rows_of(&hists),
            });
        }
        MetricsSnapshot {
            stats,
            stages: crate::obs::rows_of(&merged),
            model_stages,
            obs: self.stats.obs.counters(),
        }
    }

    /// Graceful shutdown: retire every generation (close + drain its
    /// queues — including reload-retired ones still draining), then
    /// stop the shared runtime and join its fixed worker fleet.
    pub fn shutdown(self) -> Vec<WorkerReport> {
        self.registry.shutdown();
        self.runtime.shutdown()
    }
}

#[cfg(test)]
mod tests {
    //! Exactly-once semantics of [`ReplySink`] — the contract the event
    //! plane's pipelining rests on: an admitted request delivers exactly
    //! one completion; a disarmed sink delivers zero; a dropped-unsent
    //! completion sink delivers a structured "worker gone" backstop.

    use super::*;
    use std::sync::Mutex;

    /// Captures every completion it receives.
    struct Capture(Mutex<Vec<(CompletionToken, Response)>>);

    impl Capture {
        fn new() -> Arc<Capture> {
            Arc::new(Capture(Mutex::new(Vec::new())))
        }
        fn got(&self) -> Vec<(CompletionToken, Response)> {
            self.0.lock().unwrap().clone()
        }
    }

    impl CompletionSink for Capture {
        fn complete(&self, token: CompletionToken, resp: Response) {
            self.0.lock().unwrap().push((token, resp));
        }
    }

    fn token() -> CompletionToken {
        CompletionToken { conn: 7, request: 42 }
    }

    #[test]
    fn completion_sink_delivers_exactly_once() {
        let cap = Capture::new();
        let sink = ReplySink::completion(cap.clone(), token());
        sink.send(Response::error(42, "first"));
        sink.send(Response::error(42, "second")); // dropped, not delivered
        drop(sink); // backstop must not fire after a send
        let got = cap.got();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, token());
        assert_eq!(got[0].1.error.as_deref(), Some("first"));
    }

    #[test]
    fn completion_sink_drop_backstop_reports_worker_gone() {
        let cap = Capture::new();
        drop(ReplySink::completion(cap.clone(), token()));
        let got = cap.got();
        assert_eq!(got.len(), 1, "dropped-unsent sink must deliver a backstop");
        assert_eq!(got[0].1.id, 42, "backstop echoes the client request id");
        assert_eq!(got[0].1.error.as_deref(), Some("worker gone"));
    }

    #[test]
    fn disarmed_sink_delivers_nothing() {
        let cap = Capture::new();
        let sink = ReplySink::completion(cap.clone(), token());
        sink.disarm();
        sink.send(Response::error(42, "late")); // disarm wins: already "sent"
        drop(sink);
        assert!(cap.got().is_empty(), "disarmed sink must stay silent");
    }

    #[test]
    fn channel_sink_drop_makes_recv_fail() {
        let (tx, rx) = mpsc::channel();
        drop(ReplySink::channel(tx));
        // The channel variant's backstop is mpsc's own disconnect error,
        // which callers surface as "worker gone".
        assert!(rx.recv().is_err());
    }

    #[test]
    fn channel_sink_sends_once() {
        let (tx, rx) = mpsc::channel();
        let sink = ReplySink::channel(tx);
        sink.send(Response::error(1, "only"));
        sink.send(Response::error(1, "extra"));
        assert_eq!(rx.recv().unwrap().error.as_deref(), Some("only"));
        assert!(rx.recv().is_err(), "second send must have been dropped");
    }
}
