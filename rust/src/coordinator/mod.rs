//! The serving coordinator — L3's composition root.
//!
//! ```text
//! submit(image) ──router──> worker queue (bounded, backpressured)
//!                              │  dynamic batcher (size+timeout)
//!                              ▼
//!                       worker thread: engine.infer(batch)
//!                              │
//!                              ▼
//!                 per-request Response via mpsc reply channel
//! ```
//!
//! Invariants (tested in rust/tests/coordinator_props.rs):
//! * every admitted request gets exactly one Response (success or error);
//! * rejected requests are reported as rejections, never dropped silently;
//! * FIFO within a worker queue;
//! * batch sizes ∈ supported artifact sizes;
//! * results are independent of batch packing.

pub mod batcher;
pub mod queue;
pub mod router;
pub mod worker;

use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::Config;
use crate::metrics::Histogram;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

use batcher::BatchPolicy;
use queue::BoundedQueue;
use router::{RouteError, Router};
use worker::{SharedStats, WorkerReport};

/// One inference request (image already preprocessed to 227x227x3).
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// One inference response (top-k + latency breakdown).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub top1: usize,
    pub top5: Vec<(usize, f32)>,
    /// submit -> batch formed.
    pub queue_ms: f64,
    /// engine.infer wall time for the whole batch.
    pub exec_ms: f64,
    /// submit -> response.
    pub total_ms: f64,
    pub batch_size: usize,
    pub worker: usize,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: &str) -> Response {
        Response {
            id,
            top1: 0,
            top5: Vec::new(),
            queue_ms: 0.0,
            exec_ms: 0.0,
            total_ms: 0.0,
            batch_size: 0,
            worker: usize::MAX,
            error: Some(msg.to_string()),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Submission failure modes (backpressure surface).
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// All worker queues full — retry later (the embedded device is saturated).
    Overloaded,
    /// Coordinator shutting down.
    Closed,
    /// Input had the wrong shape.
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "overloaded"),
            SubmitError::Closed => write!(f, "closed"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

/// Live stats snapshot.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub images: u64,
    pub queued: usize,
    pub latency_summary: (f64, f64, f64, f64, f64),
    pub mean_batch: f64,
}

/// The running serving system.
pub struct Coordinator {
    router: Router<Request>,
    workers: Vec<std::thread::JoinHandle<WorkerReport>>,
    next_id: AtomicU64,
    stats: Arc<SharedStats>,
    input_hw: usize,
}

impl Coordinator {
    /// Load manifest, spawn + warm all workers.  Returns only when every
    /// worker is ready to serve (compilation excluded from request
    /// latency) — or fails fast if any worker can't build its engine.
    pub fn start(cfg: &Config) -> Result<Coordinator> {
        let manifest = Manifest::load(&cfg.artifacts).context("loading manifest")?;
        let supported: Vec<usize> = match cfg.engine {
            crate::engine::EngineKind::AclStaged => manifest.batch_sizes.clone(),
            crate::engine::EngineKind::AclFused => {
                manifest.full.keys().copied().collect()
            }
            _ => vec![1],
        };
        let policy = BatchPolicy::new(cfg.max_batch, cfg.batch_timeout, &supported);

        let queues: Vec<Arc<BoundedQueue<Request>>> = (0..cfg.workers)
            .map(|_| Arc::new(BoundedQueue::new(cfg.queue_capacity)))
            .collect();
        let stats = Arc::new(SharedStats::default());
        let (ready_tx, ready_rx) = mpsc::channel();

        let mut workers = Vec::with_capacity(cfg.workers);
        for (i, q) in queues.iter().enumerate() {
            workers.push(worker::spawn_worker(
                i,
                cfg.engine,
                manifest.clone(),
                q.clone(),
                policy.clone(),
                stats.clone(),
                ready_tx.clone(),
            ));
        }
        drop(ready_tx);

        // Wait for all workers (fail fast on any engine build error).
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    for q in &queues {
                        q.close();
                    }
                    bail!("worker failed to start: {e:#}");
                }
                Err(_) => bail!("worker exited before signalling readiness"),
            }
        }

        crate::info!(
            "coordinator",
            "ready: engine={} workers={} max_batch={} supported={:?}",
            cfg.engine.as_str(),
            cfg.workers,
            cfg.max_batch,
            policy.supported
        );

        Ok(Coordinator {
            router: Router::new(queues),
            workers,
            next_id: AtomicU64::new(1),
            stats,
            input_hw: manifest.input_hw,
        })
    }

    /// Submit an image; returns the reply channel.
    pub fn submit(&self, image: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let want = [self.input_hw, self.input_hw, 3];
        if image.shape() != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {:?}, got {:?}",
                want,
                image.shape()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            submitted: Instant::now(),
            reply: tx,
        };
        match self.router.route(req) {
            Ok(_) => Ok(rx),
            Err(RouteError::Overloaded(_)) => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(RouteError::Closed(_)) => Err(SubmitError::Closed),
        }
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, image: Tensor) -> Result<Response> {
        let rx = self
            .submit(image)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        rx.recv().context("worker dropped reply channel")
    }

    pub fn stats(&self) -> StatsSnapshot {
        let lat = self.stats.latency.lock().unwrap();
        let batch = self.stats.batch_sizes.lock().unwrap();
        StatsSnapshot {
            completed: self.stats.completed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            images: self.stats.images.load(Ordering::Relaxed),
            queued: self.router.queued(),
            latency_summary: lat.summary(),
            mean_batch: batch.mean_ms(),
        }
    }

    /// Latency histogram clone (bench reporting).
    pub fn latency_histogram(&self) -> Histogram {
        self.stats.latency.lock().unwrap().clone()
    }

    /// Graceful shutdown: drain queues, join workers, return their reports.
    pub fn shutdown(self) -> Vec<WorkerReport> {
        self.router.close_all();
        self.workers
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}
