//! Bounded MPMC queue with blocking pop — the admission-control primitive.
//!
//! Mutex + Condvar (no async runtime; DESIGN.md §Substitutions).  The
//! bound is the backpressure mechanism: `try_push` on a full queue returns
//! the item to the caller, who surfaces a rejection to the client instead
//! of letting memory grow unboundedly on an embedded device.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity.min(1024)),
                closed: false,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push; `Full`/`Closed` hand the item back.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Return items to the *front* (batcher leftovers keep FIFO order).
    /// Capacity is intentionally not enforced here: the items were already
    /// admitted once.
    pub fn push_front_bulk(&self, items: Vec<T>) {
        let mut g = self.inner.lock().unwrap();
        for item in items.into_iter().rev() {
            g.items.push_front(item);
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Blocking pop with timeout.  None on timeout, or on close once the
    /// queue has drained (close is graceful: residual items still pop).
    pub fn pop_wait(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _t) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    /// Blocking pop with no timeout (None only when closed + drained).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Stable-sort pending items by a key — the deadline-ordering hook
    /// (policy::deadline::Urgency).  Stability preserves FIFO among
    /// equal keys, so plain traffic is unaffected.
    pub fn sort_pending_by_key<K: Ord>(&self, key: impl Fn(&T) -> K) {
        let mut g = self.inner.lock().unwrap();
        g.items.make_contiguous().sort_by_key(|t| key(t));
    }

    /// Minimum of `f` over pending items, ignoring `None`s — the
    /// scheduler's earliest-deadline peek (EDF override).  O(n) under
    /// the lock; queues are admission-bounded so n is small.
    pub fn min_pending_map<K: Ord>(&self, f: impl Fn(&T) -> Option<K>) -> Option<K> {
        let g = self.inner.lock().unwrap();
        g.items.iter().filter_map(|t| f(t)).min()
    }

    /// Drain up to `n` items without blocking.
    pub fn drain_up_to(&self, n: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.items.len());
        g.items.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Graceful close: existing items still drain; pushes fail; blocked
    /// poppers wake.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_wait(Duration::from_millis(1)), Some(i));
        }
        assert_eq!(q.pop_wait(Duration::from_millis(1)), None);
    }

    #[test]
    fn full_queue_rejects_with_item_back() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
    }

    #[test]
    fn close_wakes_and_drains() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(PushError::Closed(8)));
        // Residual item still pops, then None.
        assert_eq!(q.pop_blocking(), Some(7));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn push_front_bulk_preserves_order() {
        let q = BoundedQueue::new(10);
        q.try_push(3).unwrap();
        q.push_front_bulk(vec![1, 2]);
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), Some(3));
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(4));
        let qc = q.clone();
        let h = std::thread::spawn(move || qc.pop_wait(Duration::from_secs(2)));
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(42u32).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn sort_pending_is_stable() {
        let q = BoundedQueue::new(10);
        // (key, seq): equal keys must keep push order.
        for item in [(1, 0), (0, 1), (1, 2), (0, 3)] {
            q.try_push(item).unwrap();
        }
        q.sort_pending_by_key(|&(k, _)| k);
        let mut seen = Vec::new();
        while let Some(it) = q.pop_wait(Duration::from_millis(1)) {
            seen.push(it);
        }
        assert_eq!(seen, vec![(0, 1), (0, 3), (1, 0), (1, 2)]);
    }

    #[test]
    fn min_pending_map_ignores_nones() {
        let q = BoundedQueue::new(8);
        for item in [(None::<u32>, 0u32), (Some(5), 1), (Some(3), 2), (None, 3)] {
            q.try_push(item).unwrap();
        }
        assert_eq!(q.min_pending_map(|&(k, _)| k), Some(3));
        let empty = BoundedQueue::<(Option<u32>, u32)>::new(4);
        assert_eq!(empty.min_pending_map(|&(k, _)| k), None);
    }

    #[test]
    fn pop_wait_times_out() {
        let q = BoundedQueue::<u32>::new(1);
        let t0 = Instant::now();
        assert_eq!(q.pop_wait(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
