//! Weighted-fair scheduler over all live (model, engine) queues — the
//! core of the shared worker runtime (DESIGN.md §4).
//!
//! Before this refactor every model generation owned worker threads per
//! engine pool, so N models cost ~2N×`workers` threads on a 4-core SoC
//! while a traffic-skewed model left other models' workers idle.  Now a
//! **fixed** set of runtime workers (default = detected core count)
//! pulls from this scheduler, which picks the next (model, generation,
//! engine) queue to serve:
//!
//! 1. **Deadline override (EDF)**: if any non-empty queue holds a
//!    request whose absolute deadline falls inside the urgency window,
//!    the queue with the earliest such deadline is served first — a hot
//!    model cannot starve a cold model's deadlined requests.
//! 2. **Stride scheduling** otherwise: each queue accrues `pass` time
//!    at rate `images served / weight`; the backlogged queue with the
//!    lowest pass is served next, so service converges to the
//!    per-model weight ratio (weighted fair), and an idle queue that
//!    wakes up is clamped to the current minimum pass instead of
//!    replaying its idle time as a burst.
//!
//! The scheduler is also the **drain authority**: a retiring generation
//! closes its queues (graceful: residual items still pop — served by
//! the *old* weights) and [`Scheduler::wait_drained`] blocks until the
//! queue is closed, empty, *and* has zero in-flight batches, then
//! removes it from the table.  No worker threads are spawned or joined
//! per generation anymore; drain is a queue-state condition.
//!
//! Scale note: every pick scans the backlogged queues' pending items
//! for the EDF peek (O(total queued) under the one scheduler mutex,
//! which admission and charge also take).  At this repo's embedded
//! envelope — a handful of models, queues bounded at `queue_capacity`
//! — that is noise next to per-image inference cost; a deployment with
//! dozens of deep queues would want a cached per-queue min-deadline
//! maintained at push/pop instead.
//!
//! Invariants (tested here and in rust/tests/scheduler_props.rs):
//! * a pick never returns a closed *and* empty queue, but a closed
//!   non-empty queue is still served (reload drains answer everything);
//! * service proportions converge to queue weights under saturation;
//! * a queue is only deregistered once closed + empty + no in-flight
//!   batch (its arena/engines stay safe to use until then);
//! * the replica-cache byte bound is hard under eviction: retained
//!   bytes never exceed max(budget, the single entry just inserted).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::EngineKind;
use crate::policy::PolicyCtx;
use crate::registry::ModelCounters;
use crate::runtime::Manifest;
use crate::tensor::TensorPool;

use super::batcher::BatchPolicy;
use super::queue::{BoundedQueue, PushError};
use super::Request;

/// Identity of one scheduled queue.  The generation number is part of
/// the key so a reloading model's old and new queues (and the worker
/// replica caches keyed on this) never alias.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct QueueKey {
    pub model: Arc<str>,
    pub generation: u64,
    pub engine: EngineKind,
}

impl std::fmt::Display for QueueKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@g{}/{}", self.model, self.generation, self.engine.as_str())
    }
}

/// Everything a runtime worker needs to execute a batch for one
/// generation — shared by that generation's work sources.  This is what
/// replaced the per-generation `WorkerSeat`: the worker is no longer
/// seated, it visits.
pub struct ExecCtx {
    pub model: Arc<str>,
    pub generation: u64,
    /// Replica blueprint: workers build engines from this inside their
    /// own thread (XLA handles are not `Send`).
    pub manifest: Manifest,
    /// This model's tensor arena (batch buffers lease from here).
    pub arena: TensorPool,
    /// Per-generation policy state (predictor + response cache).
    pub ctx: Arc<PolicyCtx>,
    /// Per-model counters (survive reloads).
    pub counters: Arc<ModelCounters>,
    /// Per-generation stage-latency histograms (DESIGN.md §10): workers
    /// record each served batch's span deltas here; `{"cmd":"metrics"}`
    /// merges them across models.
    pub stage_hist: Arc<crate::obs::StageHist>,
    /// AOT replica snapshot for this generation, when one was loaded or
    /// captured at generation start (DESIGN.md §11): workers build their
    /// replicas from the pre-decoded buffers instead of re-reading and
    /// re-decoding the artifact directory.  `None` = snapshots disabled
    /// or unavailable — workers cold-build exactly as before.
    pub snapshot: Option<Arc<crate::runtime::ReplicaSnapshot>>,
    /// Whether snapshots are enabled for this generation (drives the
    /// `snapshot_misses` counter semantics: a cold build only counts as
    /// a miss when a snapshot *could* have served it).
    pub snapshots_on: bool,
}

/// One schedulable (model, generation, engine) queue.
pub struct WorkSource {
    pub key: QueueKey,
    pub queue: Arc<BoundedQueue<Request>>,
    pub policy: BatchPolicy,
    /// Weighted-fair share (per-model config weight; 1.0 default).
    pub weight: f64,
    /// Only the quality pool fills the response cache (DESIGN.md §7).
    pub fill_cache: bool,
    pub exec: Arc<ExecCtx>,
    /// Batches currently being executed by workers.  Incremented
    /// *before* the first pop of a batch so drain can never observe
    /// "queue empty" while a batch is mid-flight.
    inflight: AtomicUsize,
    /// Arrival-rate EWMA (req/s), fed by [`Scheduler::submit`].  The
    /// predictive warm-up scan reads it to find queues whose traffic
    /// justifies pre-building a replica while the fleet is idle.
    arrivals: Mutex<ArrivalEwma>,
}

/// EWMA of a queue's request arrival rate.  Updated per admission from
/// inter-arrival gaps; read (with a staleness clamp) by the prefetch
/// scan.
#[derive(Default)]
struct ArrivalEwma {
    last: Option<Instant>,
    /// Smoothed arrivals per second (0 until the second arrival).
    rate: f64,
}

/// Smoothing factor for the arrival EWMA — biased toward recent
/// traffic so a warm-up decision reflects the current burst, not
/// history.
const ARRIVAL_ALPHA: f64 = 0.2;

impl WorkSource {
    pub fn new(
        key: QueueKey,
        queue: Arc<BoundedQueue<Request>>,
        policy: BatchPolicy,
        weight: f64,
        fill_cache: bool,
        exec: Arc<ExecCtx>,
    ) -> WorkSource {
        WorkSource {
            key,
            queue,
            policy,
            weight: if weight.is_finite() && weight > 0.0 { weight } else { 1.0 },
            fill_cache,
            exec,
            inflight: AtomicUsize::new(0),
            arrivals: Mutex::new(ArrivalEwma::default()),
        }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Record one admission into the arrival EWMA.
    fn note_arrival(&self) {
        let now = Instant::now();
        let mut a = self.arrivals.lock().unwrap();
        if let Some(prev) = a.last {
            let dt = now.duration_since(prev).as_secs_f64().max(1e-6);
            a.rate = ARRIVAL_ALPHA * (1.0 / dt) + (1.0 - ARRIVAL_ALPHA) * a.rate;
        }
        a.last = Some(now);
    }

    /// Smoothed arrival rate in req/s, clamped by the gap since the
    /// last arrival so a queue that went quiet decays toward zero
    /// instead of holding its burst-time rate forever.
    pub fn arrival_rate(&self) -> f64 {
        let a = self.arrivals.lock().unwrap();
        match a.last {
            Some(prev) => {
                let gap = prev.elapsed().as_secs_f64().max(1e-6);
                a.rate.min(1.0 / gap)
            }
            None => 0.0,
        }
    }
}

/// RAII in-flight marker: taken by a worker before it pops a batch from
/// a source, released (with a drain wake-up) when the batch is fully
/// replied — including panic unwinds, so a dying worker can not wedge a
/// drain forever.
pub struct InflightGuard {
    source: Arc<WorkSource>,
    scheduler: Arc<Scheduler>,
}

impl InflightGuard {
    pub fn new(source: Arc<WorkSource>, scheduler: Arc<Scheduler>) -> InflightGuard {
        source.inflight.fetch_add(1, Ordering::AcqRel);
        InflightGuard { source, scheduler }
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.source.inflight.fetch_sub(1, Ordering::AcqRel);
        // Wake drain waiters.  Locked so the decrement can't slip
        // between a drain waiter's inflight check and its wait.
        let _g = self.scheduler.inner.lock().unwrap();
        self.scheduler.drain_cv.notify_all();
    }
}

/// What [`Scheduler::next`] hands a worker.
pub enum Pick {
    /// Serve this source.  `contended` = other queues also have pending
    /// work, so the worker should close its batch window immediately
    /// (work-conserving: never idle-wait while other models wait).
    Work {
        source: Arc<WorkSource>,
        contended: bool,
    },
    /// Predictive warm-up: nothing is runnable, but this queue's
    /// arrival rate crossed the prefetch threshold — build its replica
    /// now (snapshot-fast) so the next burst doesn't pay a cold build.
    /// The worker checks its own replica cache first; a replica already
    /// present makes this a no-op.
    Prefetch { source: Arc<WorkSource> },
    /// Timed out with nothing to do (worker housekeeping tick).
    Idle,
    /// Scheduler closed and every queue fully drained — exit.
    Shutdown,
}

/// One row of `{"cmd":"stats"}` scheduler introspection.
#[derive(Debug, Clone)]
pub struct QueueDepthRow {
    pub model: String,
    pub engine: &'static str,
    pub generation: u64,
    pub queued: usize,
    pub capacity: usize,
    pub weight: f64,
    pub inflight: usize,
    pub closed: bool,
}

struct Slot {
    source: Arc<WorkSource>,
    /// Stride-scheduling virtual time: images served / weight.
    pass: f64,
    /// Whether the queue was backlogged at the last pick scan.  The
    /// empty→non-empty edge is where the stride join-clamp applies.
    active: bool,
    /// Prefetch grants handed out for this queue so far.  Bounded by
    /// the fleet size (each worker has its own replica cache) and
    /// monotonic per generation: once every worker had its chance to
    /// pre-build, demand builds take over — an evicted replica is not
    /// re-prefetched (that would thrash exactly when the cache is
    /// under byte pressure).
    prefetch_grants: usize,
}

struct SchedInner {
    slots: Vec<Slot>,
    closed: bool,
    /// Scheduler-wide virtual time: the pass of the most recently
    /// chosen queue.  A queue that wakes from idle is clamped up to
    /// this at its join edge, so an idle spell banks no fair-share
    /// credit (and a long-busy queue is never locked out of the EDF
    /// override by a waking queue's stale low pass).
    vtime: f64,
    /// Predictive warm-up: arrival-rate threshold (req/s) above which
    /// an idle pick may hand out a [`Pick::Prefetch`] for a queue.
    /// 0.0 disables the scan entirely (the default).
    prefetch_threshold: f64,
    /// Max prefetch grants per queue (the worker-fleet size: one
    /// replica cache per worker).
    prefetch_grants_max: usize,
}

/// The shared-runtime scheduler (one per process, inside the
/// coordinator's `Runtime`).
pub struct Scheduler {
    inner: Mutex<SchedInner>,
    /// Workers wait here for work (submit/register/close wake it).
    cv: Condvar,
    /// Drain waiters wait here (in-flight completions wake it).  A
    /// separate condvar so an admission `notify_one` can never be
    /// consumed by a drain waiter whose condition is unmet, leaving
    /// the request unserved until an idle tick.
    drain_cv: Condvar,
    /// A queued deadline due within this window preempts fair-share
    /// order (EDF override).
    urgency_window: Duration,
    /// Bumped whenever the queue table changes (see
    /// [`Scheduler::table_epoch`]).
    table_epoch: std::sync::atomic::AtomicU64,
}

impl Scheduler {
    pub fn new(urgency_window: Duration) -> Scheduler {
        Scheduler {
            inner: Mutex::new(SchedInner {
                slots: Vec::new(),
                closed: false,
                vtime: 0.0,
                prefetch_threshold: 0.0,
                prefetch_grants_max: 0,
            }),
            cv: Condvar::new(),
            drain_cv: Condvar::new(),
            urgency_window,
            table_epoch: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Enable the predictive warm-up scan: idle picks may return
    /// [`Pick::Prefetch`] for queues whose arrival EWMA is at least
    /// `threshold` req/s, at most `grants` times per queue (the
    /// worker-fleet size).  `threshold <= 0` disables the scan.
    pub fn set_prefetch(&self, threshold: f64, grants: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefetch_threshold = if threshold.is_finite() { threshold } else { 0.0 };
        g.prefetch_grants_max = grants;
    }

    /// Register a generation's queue.  Its pass starts at the current
    /// virtual time so it cannot burst ahead of established queues.
    pub fn register(&self, source: Arc<WorkSource>) {
        let mut g = self.inner.lock().unwrap();
        let pass = g.vtime;
        g.slots.push(Slot {
            source,
            pass,
            active: false,
            prefetch_grants: 0,
        });
        drop(g);
        self.table_epoch.fetch_add(1, Ordering::AcqRel);
        self.cv.notify_all();
    }

    /// Admission: push onto the source's queue and wake a worker.
    /// `Full`/`Closed` hand the request back to the caller (the
    /// generation maps them onto `SubmitError`).
    pub fn submit(
        &self,
        source: &WorkSource,
        req: Request,
    ) -> Result<(), PushError<Request>> {
        source.queue.try_push(req)?;
        source.note_arrival();
        // Notify under the scheduler mutex: queue state lives under the
        // queue's own lock, so a bare notify could land between a
        // worker's empty-check and its wait (lost wakeup → the request
        // idles until the next tick).  Holding the mutex serializes the
        // notify against check-then-wait.
        let _g = self.inner.lock().unwrap();
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pick for a worker thread.  Serves closed queues while
    /// they still hold residual items (reload drain), skips them once
    /// empty.
    pub fn next(&self, idle_after: Duration) -> Pick {
        let deadline = Instant::now() + idle_after;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(pick) = Self::pick(&mut g, self.urgency_window) {
                return pick;
            }
            if g.closed && g.slots.iter().all(|s| s.source.queue.is_empty()) {
                // Leave residual drains to inflight guards; workers can
                // exit once nothing is poppable anywhere.
                return Pick::Shutdown;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pick::Idle;
            }
            let (ng, _t) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    fn pick(g: &mut SchedInner, urgency_window: Duration) -> Option<Pick> {
        // Join-clamp at the empty→non-empty edge: a queue that wakes
        // from idle starts at the scheduler's virtual time instead of
        // replaying its idle spell as banked credit (which would let a
        // waking burst monopolize the fleet until its stale-low pass
        // caught up, starving every busy queue — including out of the
        // EDF override, whose slack is measured against the minimum).
        let vtime = g.vtime;
        for s in g.slots.iter_mut() {
            let backlogged = !s.source.queue.is_empty();
            if backlogged && !s.active {
                s.active = true;
                s.pass = s.pass.max(vtime);
            } else if !backlogged {
                s.active = false;
            }
        }
        let candidates: Vec<usize> = g
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active)
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            // Predictive warm-up: with nothing runnable, offer an idle
            // worker a replica pre-build for a queue whose traffic says
            // a burst is live (or imminent) but whose replicas may be
            // cold.  Grants are bounded per queue so an already-warm
            // fleet can't spin here instead of idle-waiting.
            if g.prefetch_threshold > 0.0 {
                let threshold = g.prefetch_threshold;
                let max = g.prefetch_grants_max;
                if let Some(s) = g.slots.iter_mut().find(|s| {
                    s.prefetch_grants < max
                        && !s.source.queue.is_closed()
                        && s.source.arrival_rate() >= threshold
                }) {
                    s.prefetch_grants += 1;
                    return Some(Pick::Prefetch {
                        source: s.source.clone(),
                    });
                }
            }
            return None;
        }
        let contended = candidates.len() > 1;
        let base = candidates
            .iter()
            .map(|&i| g.slots[i].pass)
            .fold(f64::INFINITY, f64::min);

        // EDF override: earliest at-risk absolute deadline wins — but
        // only while the urgent queue hasn't already consumed more than
        // a few batches beyond its fair share (`EDF_PASS_SLACK`).
        // Unbounded, a stream of tight-deadline requests on one model
        // could weaponize the override to starve best-effort queues;
        // bounded, deadlines win short-term and fairness wins sustained
        // (overload demand beyond fair share sheds, as it should).
        const EDF_PASS_SLACK: f64 = 16.0;
        let now = Instant::now();
        let horizon = now + urgency_window;
        let urgent = candidates
            .iter()
            .filter(|&&i| g.slots[i].pass - base <= EDF_PASS_SLACK)
            .filter_map(|&i| {
                g.slots[i]
                    .source
                    .queue
                    .min_pending_map(|r: &Request| r.slo.deadline.map(|d| r.submitted + d))
                    .filter(|&at| at <= horizon)
                    .map(|at| (at, i))
            })
            .min_by_key(|&(at, _)| at);
        let chosen = match urgent {
            Some((_, i)) => i,
            None => {
                // Stride: lowest pass among backlogged queues.
                *candidates
                    .iter()
                    .min_by(|&&a, &&b| g.slots[a].pass.total_cmp(&g.slots[b].pass))
                    .unwrap()
            }
        };
        // Advance virtual time to the served queue's pass (monotonic;
        // an EDF pick can be at most EDF_PASS_SLACK ahead of base, so
        // joins never get penalized by more than the slack).
        let slot_pass = g.slots[chosen].pass;
        g.vtime = g.vtime.max(slot_pass);
        Some(Pick::Work {
            source: g.slots[chosen].source.clone(),
            contended,
        })
    }

    /// Charge `images` of service against a source's fair share.
    pub fn charge(&self, key: &QueueKey, images: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(slot) = g.slots.iter_mut().find(|s| &s.source.key == key) {
            slot.pass += images as f64 / slot.source.weight;
        }
    }

    /// Any *other* queue currently backlogged?  A worker holding an
    /// uncontended batch window open re-checks this between waits so a
    /// queue that wakes mid-window closes the window within one slice
    /// instead of waiting out the full coalescing timeout.
    pub fn pending_elsewhere(&self, key: &QueueKey) -> bool {
        let g = self.inner.lock().unwrap();
        g.slots
            .iter()
            .any(|s| &s.source.key != key && !s.source.queue.is_empty())
    }

    /// Monotonic epoch of the queue table (bumped on register and on
    /// drain-removal).  Workers gate their per-batch dead-replica sweep
    /// on it so steady-state serving doesn't pay an `is_live` lock per
    /// cached replica per batch.
    pub fn table_epoch(&self) -> u64 {
        self.table_epoch.load(Ordering::Acquire)
    }

    /// This queue's fair share of a `fleet`-sized worker pool, in
    /// whole workers (≥ 1): fleet × weight / Σ weights over *contended*
    /// queues (backlogged or mid-batch, always counting `key` itself).
    /// The selector uses this as a queue's drain-parallelism bound —
    /// assuming the whole fleet per queue would double-count capacity
    /// across models and admit doomed deadlined requests.
    pub fn fair_share(&self, fleet: usize, key: &QueueKey) -> usize {
        let g = self.inner.lock().unwrap();
        let mut total = 0.0f64;
        let mut own = 1.0f64;
        for s in &g.slots {
            let contended = !s.source.queue.is_empty() || s.source.inflight() > 0;
            if &s.source.key == key {
                own = s.source.weight;
                total += s.source.weight;
            } else if contended {
                total += s.source.weight;
            }
        }
        if total <= 0.0 {
            return fleet.max(1);
        }
        (((fleet as f64) * own / total).floor() as usize).clamp(1, fleet.max(1))
    }

    /// Is this queue still in the table?  Workers use it to evict
    /// replica-cache entries of fully retired generations.
    pub fn is_live(&self, key: &QueueKey) -> bool {
        let g = self.inner.lock().unwrap();
        g.slots.iter().any(|s| &s.source.key == key)
    }

    /// Block until `key`'s queue is closed, empty, and has no batch in
    /// flight, then remove it from the table.  Returns immediately if
    /// the key was never registered / already removed.  This is what a
    /// generation's retire waits on instead of joining threads.
    pub fn wait_drained(&self, key: &QueueKey) {
        let mut g = self.inner.lock().unwrap();
        loop {
            let Some(idx) = g.slots.iter().position(|s| &s.source.key == key) else {
                return;
            };
            let s = &g.slots[idx].source;
            if s.queue.is_closed() && s.queue.is_empty() && s.inflight() == 0 {
                g.slots.remove(idx);
                drop(g);
                self.table_epoch.fetch_add(1, Ordering::AcqRel);
                // Wake workers: the candidate set changed (and during
                // shutdown, the all-drained exit condition may now hold).
                self.cv.notify_all();
                return;
            }
            // Drain waiters have their own condvar so they can never
            // consume a worker's work-available notification.
            let (ng, _t) = self
                .drain_cv
                .wait_timeout(g, Duration::from_millis(50))
                .unwrap();
            g = ng;
        }
    }

    /// Per-queue depth rows for `{"cmd":"stats"}`.
    pub fn queue_rows(&self) -> Vec<QueueDepthRow> {
        let g = self.inner.lock().unwrap();
        g.slots
            .iter()
            .map(|s| QueueDepthRow {
                model: s.source.key.model.to_string(),
                engine: s.source.key.engine.as_str(),
                generation: s.source.key.generation,
                queued: s.source.queue.len(),
                capacity: s.source.queue.capacity(),
                weight: s.source.weight,
                inflight: s.source.inflight(),
                closed: s.source.queue.is_closed(),
            })
            .collect()
    }

    /// Global shutdown: workers exit once every queue is drained.
    /// Queues themselves are closed by their generations' retire.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Wake every worker.  Locked for the same lost-wakeup reason as
    /// [`Scheduler::submit`]: callers mutate queue state outside this
    /// mutex (batcher leftovers, queue close).
    pub(super) fn notify_all(&self) {
        let _g = self.inner.lock().unwrap();
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Replica cache: byte-bounded LRU of engine replicas, one per worker.
// ---------------------------------------------------------------------------

/// Estimate of the resident bytes one engine replica of `kind` costs
/// (weights dominate; int8 engines hold the q8 table, fp32 engines the
/// fp32 table; a fixed slack covers executables and scratch).
pub fn replica_bytes(kind: EngineKind, manifest: &Manifest) -> usize {
    const SLACK: usize = 1 << 20; // executables, literals, scratch
    let fp32: usize = manifest.params.iter().map(|p| p.nelems * 4).sum();
    let q8: usize = manifest.params_q8.iter().map(|p| p.nelems).sum();
    match kind {
        EngineKind::Quant => q8 + SLACK,
        EngineKind::Sim => SLACK,
        _ => fp32 + SLACK,
    }
}

struct CacheEntry<T> {
    key: QueueKey,
    value: T,
    bytes: usize,
    last_used: u64,
}

/// A worker-private, memory-bounded LRU of engine replicas keyed by
/// (model, generation, engine).  Generic over the stored value so the
/// byte-bound invariant is property-testable without engines.
///
/// The bound is **hard under eviction**: after any insert, retained
/// bytes ≤ max(budget, bytes of the entry just inserted) — a single
/// oversized replica is kept alone (the worker must make progress), but
/// never together with anything else.
pub struct ReplicaCache<T> {
    budget: usize,
    entries: Vec<CacheEntry<T>>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl<T> ReplicaCache<T> {
    pub fn new(budget_bytes: usize) -> ReplicaCache<T> {
        ReplicaCache {
            budget: budget_bytes.max(1),
            entries: Vec::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Borrow a cached replica without touching recency or the
    /// hit/miss counters — for re-borrowing an entry the caller just
    /// inserted or already counted (a second counting `get` would
    /// inflate the hit rate exactly when the cache thrashes).
    pub fn get_quiet(&mut self, key: &QueueKey) -> Option<&mut T> {
        self.entries
            .iter_mut()
            .find(|e| &e.key == key)
            .map(|e| &mut e.value)
    }

    /// Borrow a cached replica, bumping its recency.
    pub fn get(&mut self, key: &QueueKey) -> Option<&mut T> {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.iter_mut().find(|e| &e.key == key) {
            Some(e) => {
                e.last_used = tick;
                self.hits += 1;
                Some(&mut e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (replacing any same-key entry), then evict LRU entries —
    /// never the one just inserted — until the byte bound holds.
    /// Returns the evicted values so callers can fold their state
    /// (engine ledgers) into reports instead of silently losing it in
    /// exactly the cache-thrash configurations worth observing.
    pub fn insert(&mut self, key: QueueKey, value: T, bytes: usize) -> Vec<T> {
        self.tick += 1;
        let tick = self.tick;
        let mut evicted: Vec<T> = Vec::new();
        if let Some(i) = self.entries.iter().position(|e| e.key == key) {
            evicted.push(self.entries.remove(i).value);
        }
        self.entries.push(CacheEntry {
            key: key.clone(),
            value,
            bytes,
            last_used: tick,
        });
        while self.total_bytes() > self.budget && self.entries.len() > 1 {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| e.key != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            match lru {
                Some(i) => {
                    evicted.push(self.entries.remove(i).value);
                    self.evictions += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Drop entries whose key no longer satisfies `live` (retired
    /// generations), returning the evicted values so callers can fold
    /// their state (e.g. engine ledgers) into reports.
    pub fn evict_dead(&mut self, live: impl Fn(&QueueKey) -> bool) -> Vec<T> {
        let mut dead = Vec::new();
        let mut kept = Vec::new();
        for e in self.entries.drain(..) {
            if live(&e.key) {
                kept.push(e);
            } else {
                dead.push(e.value);
            }
        }
        self.evictions += dead.len() as u64;
        self.entries = kept;
        dead
    }

    /// Drain everything (worker exit).
    pub fn drain(&mut self) -> Vec<T> {
        self.entries.drain(..).map(|e| e.value).collect()
    }
}

// ---------------------------------------------------------------------------
// Runtime: the fixed worker fleet + occupancy accounting.
// ---------------------------------------------------------------------------

/// Per-worker live counters (occupancy for `{"cmd":"stats"}`).
#[derive(Default)]
pub struct WorkerSlot {
    pub batches: std::sync::atomic::AtomicU64,
    pub images: std::sync::atomic::AtomicU64,
    pub busy_us: std::sync::atomic::AtomicU64,
}

/// One row of worker-occupancy introspection.
#[derive(Debug, Clone)]
pub struct WorkerOccupancyRow {
    pub worker: usize,
    pub batches: u64,
    pub images: u64,
    /// Fraction of wall time since runtime start spent serving batches.
    pub busy_frac: f64,
}

/// Cloneable handle the registry/generations use to reach the runtime.
#[derive(Clone)]
pub struct RuntimeHandle {
    pub scheduler: Arc<Scheduler>,
    /// Fixed worker-fleet size (the selector's drain-rate bound).
    pub workers: usize,
}

/// The shared worker runtime: a fixed fleet of threads over one
/// scheduler.  Spawned once by the coordinator; generations only
/// register/deregister queues.
pub struct Runtime {
    handle: RuntimeHandle,
    slots: Arc<Vec<WorkerSlot>>,
    started: Instant,
    handles: Mutex<Vec<std::thread::JoinHandle<super::worker::WorkerReport>>>,
}

impl Runtime {
    /// Spawn `workers` runtime threads (clamped ≥ 1).
    pub fn start(
        workers: usize,
        replica_cache_bytes: usize,
        urgency_window: Duration,
        stats: Arc<super::worker::SharedStats>,
    ) -> Runtime {
        let workers = workers.max(1);
        let scheduler = Arc::new(Scheduler::new(urgency_window));
        let slots: Arc<Vec<WorkerSlot>> =
            Arc::new((0..workers).map(|_| WorkerSlot::default()).collect());
        let mut handles = Vec::with_capacity(workers);
        for index in 0..workers {
            handles.push(super::worker::spawn_runtime_worker(
                super::worker::RuntimeWorker {
                    index,
                    scheduler: scheduler.clone(),
                    stats: stats.clone(),
                    slots: slots.clone(),
                    replica_cache_bytes,
                },
            ));
        }
        Runtime {
            handle: RuntimeHandle { scheduler, workers },
            slots,
            started: Instant::now(),
            handles: Mutex::new(handles),
        }
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    pub fn workers(&self) -> usize {
        self.handle.workers
    }

    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.handle.scheduler
    }

    /// Worker-occupancy rows for `{"cmd":"stats"}`.
    pub fn occupancy(&self) -> Vec<WorkerOccupancyRow> {
        let wall_us = self.started.elapsed().as_micros().max(1) as f64;
        self.slots
            .iter()
            .enumerate()
            .map(|(worker, s)| WorkerOccupancyRow {
                worker,
                batches: s.batches.load(Ordering::Relaxed),
                images: s.images.load(Ordering::Relaxed),
                busy_frac: (s.busy_us.load(Ordering::Relaxed) as f64 / wall_us).min(1.0),
            })
            .collect()
    }

    /// Close the scheduler and join every worker.  Call only after the
    /// registry has retired (drained) all generations.  A worker that
    /// died panicking is logged and skipped rather than re-panicking —
    /// this same path runs from `Drop`, where a second panic aborts.
    pub fn shutdown(self) -> Vec<super::worker::WorkerReport> {
        self.shutdown_impl()
        // `self` drops here; Drop re-runs shutdown_impl, which finds
        // the scheduler already closed and no handles left — a no-op.
    }

    fn shutdown_impl(&self) -> Vec<super::worker::WorkerReport> {
        self.handle.scheduler.close();
        let handles: Vec<_> = std::mem::take(&mut *self.handles.lock().unwrap());
        handles
            .into_iter()
            .filter_map(|h| match h.join() {
                Ok(report) => Some(report),
                Err(_) => {
                    crate::error!("runtime", "a runtime worker panicked; report lost");
                    None
                }
            })
            .collect()
    }
}

impl Drop for Runtime {
    /// Backstop for coordinators dropped without an explicit shutdown
    /// (a test unwinding mid-body, an embedder's early-error return):
    /// without this, the fixed fleet would leak as detached threads
    /// spinning on idle ticks forever.  Generations drop before the
    /// runtime (field order in `Coordinator`), so their drains still
    /// see live workers.
    fn drop(&mut self) {
        let _ = self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::sched::{dummy_request, sim_source};

    fn test_source(model: &str, weight: f64, cap: usize) -> Arc<WorkSource> {
        sim_source(model, weight, cap)
    }

    fn test_request(deadline_ms: Option<f64>) -> Request {
        dummy_request(0, deadline_ms)
    }

    fn drain_one(s: &Arc<WorkSource>) -> usize {
        s.queue.drain_up_to(1).len()
    }

    #[test]
    fn stride_service_tracks_weights() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let heavy = test_source("heavy", 3.0, 256);
        let light = test_source("light", 1.0, 256);
        sched.register(heavy.clone());
        sched.register(light.clone());

        // Keep both backlogged; serve 400 singles via the scheduler.
        let mut served = [0usize; 2];
        for _ in 0..400 {
            while heavy.queue.len() < 4 {
                sched.submit(&heavy, test_request(None)).unwrap();
            }
            while light.queue.len() < 4 {
                sched.submit(&light, test_request(None)).unwrap();
            }
            match sched.next(Duration::from_millis(10)) {
                Pick::Work { source, .. } => {
                    let n = drain_one(&source);
                    sched.charge(&source.key, n);
                    if source.key.model.as_ref() == "heavy" {
                        served[0] += n;
                    } else {
                        served[1] += n;
                    }
                }
                _ => panic!("expected work"),
            }
        }
        let ratio = served[0] as f64 / served[1].max(1) as f64;
        assert!(
            (2.0..=4.5).contains(&ratio),
            "service ratio {ratio:.2} (heavy {} / light {}) strays from 3:1",
            served[0],
            served[1]
        );
    }

    #[test]
    fn waking_queue_banks_no_idle_credit() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let busy = test_source("busy", 1.0, 256);
        let lazy = test_source("lazy", 1.0, 256);
        sched.register(busy.clone());
        sched.register(lazy.clone());

        // Serve 50 images from busy while lazy idles (its pass stays
        // stale-low; virtual time advances with busy).
        for _ in 0..50 {
            while busy.queue.len() < 2 {
                sched.submit(&busy, test_request(None)).unwrap();
            }
            match sched.next(Duration::from_millis(10)) {
                Pick::Work { source, .. } => {
                    assert_eq!(source.key.model.as_ref(), "busy");
                    let n = drain_one(&source);
                    sched.charge(&source.key, n);
                }
                _ => panic!("expected work"),
            }
        }

        // Lazy wakes with a sustained burst: the join-clamp must bring
        // its pass up to virtual time so it SHARES the fleet instead of
        // monopolizing it for 50 images of banked idle "credit".
        let mut served = [0usize; 2]; // [busy, lazy]
        for _ in 0..20 {
            while busy.queue.len() < 2 {
                sched.submit(&busy, test_request(None)).unwrap();
            }
            while lazy.queue.len() < 2 {
                sched.submit(&lazy, test_request(None)).unwrap();
            }
            match sched.next(Duration::from_millis(10)) {
                Pick::Work { source, .. } => {
                    let n = drain_one(&source);
                    sched.charge(&source.key, n);
                    if source.key.model.as_ref() == "busy" {
                        served[0] += n;
                    } else {
                        served[1] += n;
                    }
                }
                _ => panic!("expected work"),
            }
        }
        assert!(served[0] >= 6, "busy starved by a waking queue: {served:?}");
        assert!(served[1] >= 6, "waking queue starved: {served:?}");

        // The long-busy queue's deadlines stay EDF-eligible: its pass
        // sits within the slack of the clamped-up base.
        sched.submit(&busy, test_request(Some(20.0))).unwrap();
        while lazy.queue.len() < 2 {
            sched.submit(&lazy, test_request(None)).unwrap();
        }
        match sched.next(Duration::from_millis(10)) {
            Pick::Work { source, .. } => {
                assert_eq!(source.key.model.as_ref(), "busy")
            }
            _ => panic!("expected work"),
        }
    }

    #[test]
    fn fair_share_splits_fleet_by_contention_and_weight() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let a = test_source("fsa", 1.0, 8);
        let b = test_source("fsb", 3.0, 8);
        sched.register(a.clone());
        sched.register(b.clone());
        // Nothing contended: a queue gets the whole fleet.
        assert_eq!(sched.fair_share(4, &a.key), 4);
        // Both backlogged: split by weight (1:3 of 4 workers → 1 and 3).
        sched.submit(&a, test_request(None)).unwrap();
        sched.submit(&b, test_request(None)).unwrap();
        assert_eq!(sched.fair_share(4, &a.key), 1);
        assert_eq!(sched.fair_share(4, &b.key), 3);
        // Never below one worker, never above the fleet.
        assert_eq!(sched.fair_share(1, &a.key), 1);
        // Unknown key defaults to weight 1 against the contended set.
        let ghost = QueueKey {
            model: Arc::from("ghost"),
            generation: 9,
            engine: EngineKind::Sim,
        };
        assert!(sched.fair_share(4, &ghost) >= 1);
    }

    #[test]
    fn edf_override_preempts_fair_share() {
        let sched = Scheduler::new(Duration::from_millis(100));
        let hot = test_source("hot", 1.0, 256);
        let cold = test_source("cold", 1.0, 256);
        sched.register(hot.clone());
        sched.register(cold.clone());
        for _ in 0..8 {
            sched.submit(&hot, test_request(None)).unwrap();
        }
        // Cold's single request has a deadline inside the urgency
        // window: it must be picked first despite hot's backlog.
        sched.submit(&cold, test_request(Some(20.0))).unwrap();
        match sched.next(Duration::from_millis(10)) {
            Pick::Work { source, contended } => {
                assert_eq!(source.key.model.as_ref(), "cold");
                assert!(contended);
            }
            _ => panic!("expected work"),
        }
    }

    #[test]
    fn closed_nonempty_queue_still_served_then_drains() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let s = test_source("draining", 1.0, 8);
        sched.register(s.clone());
        sched.submit(&s, test_request(None)).unwrap();
        s.queue.close();
        // Residual item is still pickable (reload drain semantics).
        match sched.next(Duration::from_millis(10)) {
            Pick::Work { source, .. } => {
                assert_eq!(drain_one(&source), 1);
                sched.charge(&source.key, 1);
            }
            _ => panic!("closed non-empty queue must still be served"),
        }
        // Now closed + empty + no inflight: wait_drained removes it.
        sched.wait_drained(&s.key);
        assert!(!sched.is_live(&s.key));
        // Idempotent for unknown keys.
        sched.wait_drained(&s.key);
    }

    #[test]
    fn wait_drained_blocks_on_inflight() {
        let sched = Arc::new(Scheduler::new(Duration::from_millis(50)));
        let s = test_source("inflight", 1.0, 8);
        sched.register(s.clone());
        s.queue.close();
        let guard = InflightGuard::new(s.clone(), sched.clone());
        let (sched2, key) = (sched.clone(), s.key.clone());
        let waiter = std::thread::spawn(move || {
            sched2.wait_drained(&key);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!waiter.is_finished(), "drain completed with a batch in flight");
        drop(guard);
        waiter.join().unwrap();
        assert!(!sched.is_live(&s.key));
    }

    #[test]
    fn close_shuts_down_idle_pick() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let s = test_source("bye", 1.0, 8);
        sched.register(s.clone());
        sched.close();
        assert!(matches!(sched.next(Duration::from_millis(5)), Pick::Shutdown));
    }

    #[test]
    fn idle_pick_times_out() {
        let sched = Scheduler::new(Duration::from_millis(50));
        let t0 = Instant::now();
        assert!(matches!(sched.next(Duration::from_millis(20)), Pick::Idle));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn replica_cache_lru_and_byte_bound() {
        let key = |m: &str| QueueKey {
            model: Arc::from(m),
            generation: 1,
            engine: EngineKind::Sim,
        };
        let mut c: ReplicaCache<u32> = ReplicaCache::new(100);
        c.insert(key("a"), 1, 40);
        c.insert(key("b"), 2, 40);
        assert_eq!(c.total_bytes(), 80);
        // Touch a so b is the LRU victim — and the evicted value comes
        // back to the caller (ledger folding), never silently dropped.
        assert_eq!(c.get(&key("a")), Some(&mut 1));
        assert_eq!(c.insert(key("c"), 3, 40), vec![2]);
        assert_eq!(c.total_bytes(), 80);
        assert!(c.get(&key("b")).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key("a")).is_some());
        // An oversized entry is kept alone — never with company — and
        // both displaced values are handed back (LRU first).
        assert_eq!(c.insert(key("d"), 4, 500), vec![3, 1]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.total_bytes(), 500);
        // A small entry next to the oversized LRU restores the bound by
        // evicting the giant.
        c.insert(key("e"), 5, 10);
        assert_eq!(c.len(), 1);
        assert_eq!(c.total_bytes(), 10);
        // evict_dead removes retired keys, returning their values.
        c.insert(key("f"), 6, 20);
        assert_eq!(c.len(), 2);
        let dead = c.evict_dead(|k| k.model.as_ref() == "f");
        assert_eq!(dead, vec![5]);
        assert_eq!(c.len(), 1);
        assert!(c.get(&key("f")).is_some());
    }

    #[test]
    fn replica_bytes_scales_by_kind() {
        let dir = std::env::temp_dir().join(format!(
            "zuluko_sched_bytes_{}",
            std::process::id()
        ));
        crate::testkit::manifest::write_synthetic(&dir, "m", 10, 8, &[1]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let fp32 = replica_bytes(EngineKind::AclStaged, &m);
        let q8 = replica_bytes(EngineKind::Quant, &m);
        let sim = replica_bytes(EngineKind::Sim, &m);
        assert!(fp32 >= sim);
        assert!(q8 >= sim || m.params_q8.is_empty());
    }
}
