//! Request router: spreads admitted requests across worker queues.
//!
//! Round-robin with least-loaded fallback: the round-robin target is
//! tried first; if its queue is full the router picks the shortest queue
//! instead; only when *every* queue is full does the request bounce back
//! to the client as backpressure (vllm-router-style admission).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::queue::{BoundedQueue, PushError};

/// Routing outcome errors.
#[derive(Debug, PartialEq, Eq)]
pub enum RouteError<T> {
    /// All queues full — caller should surface a rejection.
    Overloaded(T),
    /// Shutting down.
    Closed(T),
}

pub struct Router<T> {
    queues: Vec<Arc<BoundedQueue<T>>>,
    next: AtomicUsize,
}

impl<T> Router<T> {
    pub fn new(queues: Vec<Arc<BoundedQueue<T>>>) -> Router<T> {
        assert!(!queues.is_empty(), "router needs >= 1 queue");
        Router {
            queues,
            next: AtomicUsize::new(0),
        }
    }

    pub fn queues(&self) -> &[Arc<BoundedQueue<T>>] {
        &self.queues
    }

    /// Route one request.  Returns the chosen queue index.
    pub fn route(&self, item: T) -> Result<usize, RouteError<T>> {
        let n = self.queues.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;

        // 1) round-robin target
        let mut item = match self.queues[start].try_push(item) {
            Ok(()) => return Ok(start),
            Err(PushError::Closed(it)) => return Err(RouteError::Closed(it)),
            Err(PushError::Full(it)) => it,
        };

        // 2) least-loaded fallback over the remaining queues
        let mut order: Vec<usize> = (0..n).filter(|&i| i != start).collect();
        order.sort_by_key(|&i| self.queues[i].len());
        for i in order {
            item = match self.queues[i].try_push(item) {
                Ok(()) => return Ok(i),
                Err(PushError::Closed(it)) => return Err(RouteError::Closed(it)),
                Err(PushError::Full(it)) => it,
            };
        }
        Err(RouteError::Overloaded(item))
    }

    /// Total queued across all workers (load metric).
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Total admission slots across all queues (the selector's
    /// "pool full" bound).
    pub fn capacity(&self) -> usize {
        self.queues.iter().map(|q| q.capacity()).sum()
    }

    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, cap: usize) -> Router<u32> {
        Router::new((0..n).map(|_| Arc::new(BoundedQueue::new(cap))).collect())
    }

    #[test]
    fn round_robin_spreads() {
        let r = mk(3, 8);
        let mut hits = [0usize; 3];
        for i in 0..9 {
            hits[r.route(i).unwrap()] += 1;
        }
        assert_eq!(hits, [3, 3, 3]);
    }

    #[test]
    fn full_target_falls_to_least_loaded() {
        let r = mk(2, 2);
        // Fill queue 0.
        r.queues()[0].try_push(100).unwrap();
        r.queues()[0].try_push(101).unwrap();
        // Route four items; all must land in queue 1.
        let mut q1 = 0;
        for i in 0..2 {
            let idx = r.route(i).unwrap();
            if idx == 1 {
                q1 += 1;
            }
        }
        assert_eq!(q1, 2);
    }

    #[test]
    fn overload_returns_item() {
        let r = mk(2, 1);
        r.route(1).unwrap();
        r.route(2).unwrap();
        match r.route(3) {
            Err(RouteError::Overloaded(3)) => {}
            other => panic!("expected Overloaded(3), got {other:?}"),
        }
    }

    #[test]
    fn closed_propagates() {
        let r = mk(1, 4);
        r.close_all();
        assert!(matches!(r.route(9), Err(RouteError::Closed(9))));
    }
}
