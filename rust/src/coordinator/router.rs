//! Admission ports: a generation's submit-side handle on its scheduled
//! queues.
//!
//! The pre-runtime router spread requests across per-worker queues
//! (workers were pinned, so load balancing happened at admission).
//! Under the shared runtime (DESIGN.md §4) there is exactly **one**
//! bounded queue per (model, engine) and the balancing moved to the
//! scheduler's pick side — admission only has to enforce backpressure
//! and wake a worker.  `EnginePort` is that surface: `admit` pushes
//! onto the queue through the scheduler (so the notify can never be
//! forgotten) and maps queue-full / queue-closed onto the same
//! [`RouteError`] contract the selector path always handled.
//!
//! Invariants (tested here and in rust/tests/coordinator_props.rs):
//! * conservation: every admitted request is in the queue exactly once;
//!   every refused request comes back to the caller inside the error;
//! * `Overloaded` only when the queue is truly at capacity;
//! * `Closed` propagates a retiring generation (callers re-resolve).

use std::sync::Arc;

use crate::policy::PoolView;

use super::queue::PushError;
use super::scheduler::{Scheduler, WorkSource};
use super::Request;

/// Admission outcome errors (same contract as the old router).
#[derive(Debug, PartialEq, Eq)]
pub enum RouteError<T> {
    /// Queue full — caller should surface a rejection.
    Overloaded(T),
    /// Generation retiring / shutting down.
    Closed(T),
}

/// One engine's admission port within a generation: the (model, engine)
/// queue plus the scheduler that serves it.
pub struct EnginePort {
    source: Arc<WorkSource>,
    scheduler: Arc<Scheduler>,
}

impl EnginePort {
    pub fn new(source: Arc<WorkSource>, scheduler: Arc<Scheduler>) -> EnginePort {
        EnginePort { source, scheduler }
    }

    pub fn source(&self) -> &Arc<WorkSource> {
        &self.source
    }

    pub fn kind(&self) -> crate::engine::EngineKind {
        self.source.key.engine
    }

    /// Admit one request: push + worker wake-up, or hand it back.
    pub fn admit(&self, req: Request) -> Result<(), RouteError<Request>> {
        match self.scheduler.submit(&self.source, req) {
            Ok(()) => Ok(()),
            Err(PushError::Full(r)) => Err(RouteError::Overloaded(r)),
            Err(PushError::Closed(r)) => Err(RouteError::Closed(r)),
        }
    }

    pub fn queued(&self) -> usize {
        self.source.queue.len()
    }

    pub fn capacity(&self) -> usize {
        self.source.queue.capacity()
    }

    /// Close the queue (graceful: residual items still drain through
    /// the runtime, served by this generation's weights).
    pub fn close(&self) {
        self.source.queue.close();
        // Wake workers so residual items drain promptly.
        self.scheduler.notify_all();
    }

    /// Selector-facing snapshot.  `fleet` is the shared runtime's
    /// total worker count; the reported `workers` is this queue's
    /// *fair share* of it under current contention (≥ 1), so the
    /// completion prediction doesn't assume every queue drains with
    /// the whole fleet at once.
    pub fn view(&self, fleet: usize) -> PoolView {
        let share = self.scheduler.fair_share(fleet, &self.source.key);
        self.view_with(share)
    }

    /// Like [`EnginePort::view`] with a precomputed worker share — the
    /// submit path computes the fair share once per request instead of
    /// taking the scheduler lock once per port (a generation's ports
    /// share its model weight, so their shares differ only by the
    /// sibling queue's own momentary contention).
    pub fn view_with(&self, share: usize) -> PoolView {
        PoolView {
            kind: self.source.key.engine,
            queued: self.queued(),
            workers: share,
            capacity: self.capacity(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineKind;
    use crate::testkit::sched::{dummy_request, sim_source};
    use std::time::Duration;

    fn port(cap: usize) -> (EnginePort, Arc<Scheduler>) {
        let source = sim_source("rt", 1.0, cap);
        let scheduler = Arc::new(Scheduler::new(Duration::from_millis(50)));
        scheduler.register(source.clone());
        (EnginePort::new(source, scheduler.clone()), scheduler)
    }

    fn req(id: u64) -> Request {
        dummy_request(id, None)
    }

    #[test]
    fn admits_until_full_then_bounces_the_item() {
        let (p, _s) = port(2);
        p.admit(req(1)).unwrap();
        p.admit(req(2)).unwrap();
        assert_eq!(p.queued(), 2);
        match p.admit(req(3)) {
            Err(RouteError::Overloaded(r)) => assert_eq!(r.id, 3),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn closed_propagates_with_the_item() {
        let (p, _s) = port(4);
        p.close();
        match p.admit(req(9)) {
            Err(RouteError::Closed(r)) => assert_eq!(r.id, 9),
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn view_reports_queue_and_fleet() {
        let (p, _s) = port(8);
        p.admit(req(1)).unwrap();
        let v = p.view(3);
        assert_eq!(v.kind, EngineKind::Sim);
        assert_eq!(v.queued, 1);
        assert_eq!(v.workers, 3);
        assert_eq!(v.capacity, 8);
    }
}
