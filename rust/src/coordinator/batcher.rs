//! Dynamic batcher: size + timeout policy over the admission queue.
//!
//! vLLM-style request coalescing scaled to an embedded engine: wait for
//! the first request (no deadline — idle costs nothing), then hold the
//! batch open up to `timeout` or until `max_batch` requests arrived, then
//! shrink to the largest batch size that has a compiled artifact and
//! return the leftovers to the queue front (FIFO preserved).
//!
//! Invariants (property-tested in rust/tests/coordinator_props.rs):
//! * returned batch size is always in `supported`;
//! * batch ≤ max_batch;
//! * leftovers keep their relative order;
//! * a non-empty queue never yields an empty batch;
//! * the batch window never stretches past `timeout`, even when a
//!   sustained burst keeps the fast-path drain busy.

use std::time::{Duration, Instant};

use super::queue::BoundedQueue;

/// Batch formation policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
    /// Batch sizes with compiled artifacts, ascending (e.g. [1,2,4,8]).
    pub supported: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, timeout: Duration, supported: &[usize]) -> BatchPolicy {
        let mut s: Vec<usize> = supported.iter().copied().filter(|&b| b > 0).collect();
        s.sort_unstable();
        s.dedup();
        if !s.contains(&1) {
            s.insert(0, 1);
        }
        BatchPolicy {
            max_batch: max_batch.max(1),
            timeout,
            supported: s,
        }
    }

    /// Largest supported size <= n (n >= 1 guarantees an answer since 1 is
    /// always supported).
    pub fn fit(&self, n: usize) -> usize {
        self.supported
            .iter()
            .copied()
            .filter(|&b| b <= n && b <= self.max_batch)
            .max()
            .unwrap_or(1)
    }

    /// Pure batch-shrink step: split `items` into (batch, leftovers).
    pub fn split<T>(&self, mut items: Vec<T>) -> (Vec<T>, Vec<T>) {
        let keep = self.fit(items.len().max(1)).min(items.len());
        let rest = items.split_off(keep);
        (items, rest)
    }

    /// Form one batch from the queue.  Blocks for the first item; returns
    /// None when the queue is closed and drained.
    pub fn form<T>(&self, queue: &BoundedQueue<T>) -> Option<Vec<T>> {
        let first = queue.pop_blocking()?;
        let mut items = vec![first];
        let deadline = Instant::now() + self.timeout;
        while items.len() < self.max_batch {
            // Fast path: grab whatever is already waiting.
            let mut more = queue.drain_up_to(self.max_batch - items.len());
            if !more.is_empty() {
                items.append(&mut more);
                // A sustained burst must not extend the batch window: a
                // queue that refills as fast as we drain would otherwise
                // keep this loop in the fast path forever.  Check the
                // deadline before re-draining.
                if Instant::now() >= deadline {
                    break;
                }
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.pop_wait(deadline - now) {
                Some(item) => items.push(item),
                None => break, // timeout or closed
            }
        }
        let (batch, rest) = self.split(items);
        if !rest.is_empty() {
            queue.push_front_bulk(rest);
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max: usize) -> BatchPolicy {
        BatchPolicy::new(max, Duration::from_millis(5), &[1, 2, 4, 8])
    }

    #[test]
    fn fit_picks_largest_supported() {
        let p = policy(8);
        assert_eq!(p.fit(1), 1);
        assert_eq!(p.fit(3), 2);
        assert_eq!(p.fit(4), 4);
        assert_eq!(p.fit(7), 4);
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(100), 8);
    }

    #[test]
    fn fit_respects_max_batch() {
        let p = policy(2);
        assert_eq!(p.fit(8), 2);
    }

    #[test]
    fn one_is_always_supported() {
        let p = BatchPolicy::new(4, Duration::ZERO, &[4]);
        assert_eq!(p.fit(3), 1);
    }

    #[test]
    fn split_keeps_order() {
        let p = policy(8);
        let (batch, rest) = p.split(vec![1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(batch, vec![1, 2, 3, 4]);
        assert_eq!(rest, vec![5, 6, 7]);
    }

    #[test]
    fn form_collects_waiting_items() {
        let q = BoundedQueue::new(16);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let p = policy(8);
        let batch = p.form(&q).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]); // fit(5)=4
        assert_eq!(q.len(), 1); // leftover back in queue
        let batch2 = p.form(&q).unwrap();
        assert_eq!(batch2, vec![4]);
    }

    #[test]
    fn form_returns_none_on_closed_empty() {
        let q = BoundedQueue::<u32>::new(4);
        q.close();
        assert_eq!(policy(4).form(&q), None);
    }

    #[test]
    fn sustained_burst_cannot_extend_window_past_timeout() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // A producer refills the queue as fast as form() drains it; the
        // old fast-path `continue` never re-checked the deadline, so the
        // window stretched until max_batch filled.  With max_batch far
        // above what the window can collect, form() must still return
        // within (roughly) the timeout.
        let q = Arc::new(BoundedQueue::new(1024));
        let stop = Arc::new(AtomicBool::new(false));
        let producer = {
            let q = q.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    let _ = q.try_push(i);
                    i = i.wrapping_add(1);
                }
            })
        };
        let p = BatchPolicy::new(1_000_000, Duration::from_millis(30), &[1, 2, 4, 8]);
        let t0 = Instant::now();
        let batch = p.form(&q).unwrap();
        let elapsed = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        producer.join().unwrap();
        assert!(!batch.is_empty());
        assert!(
            elapsed < Duration::from_millis(500),
            "batch window stretched to {elapsed:?} under sustained load"
        );
    }

    #[test]
    fn form_times_out_to_small_batch() {
        let q = BoundedQueue::new(4);
        q.try_push(9u32).unwrap();
        let t0 = Instant::now();
        let batch = policy(8).form(&q).unwrap();
        assert_eq!(batch, vec![9]);
        assert!(t0.elapsed() >= Duration::from_millis(4), "must wait the window");
    }
}
