//! Dynamic batcher: size + timeout policy over the admission queue.
//!
//! vLLM-style request coalescing scaled to an embedded engine: wait for
//! the first request (no deadline — idle costs nothing), then hold the
//! batch open up to `timeout` or until `max_batch` requests arrived, then
//! shrink to the largest batch size that has a compiled artifact and
//! return the leftovers to the queue front (FIFO preserved).
//!
//! Invariants (property-tested in rust/tests/coordinator_props.rs):
//! * returned batch size is always in `supported`;
//! * batch ≤ max_batch;
//! * leftovers keep their relative order;
//! * a non-empty queue never yields an empty batch;
//! * the batch window never stretches past `timeout`, even when a
//!   sustained burst keeps the fast-path drain busy.

use std::time::{Duration, Instant};

use super::queue::BoundedQueue;

/// Batch formation policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
    /// Batch sizes with compiled artifacts, ascending (e.g. [1,2,4,8]).
    pub supported: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, timeout: Duration, supported: &[usize]) -> BatchPolicy {
        let mut s: Vec<usize> = supported.iter().copied().filter(|&b| b > 0).collect();
        s.sort_unstable();
        s.dedup();
        if !s.contains(&1) {
            s.insert(0, 1);
        }
        BatchPolicy {
            max_batch: max_batch.max(1),
            timeout,
            supported: s,
        }
    }

    /// Largest supported size <= n (n >= 1 guarantees an answer since 1 is
    /// always supported).
    pub fn fit(&self, n: usize) -> usize {
        self.supported
            .iter()
            .copied()
            .filter(|&b| b <= n && b <= self.max_batch)
            .max()
            .unwrap_or(1)
    }

    /// Pure batch-shrink step: split `items` into (batch, leftovers).
    pub fn split<T>(&self, mut items: Vec<T>) -> (Vec<T>, Vec<T>) {
        let keep = self.fit(items.len().max(1)).min(items.len());
        let rest = items.split_off(keep);
        (items, rest)
    }

    /// Form one batch from the queue.  Blocks for the first item; returns
    /// None when the queue is closed and drained.
    pub fn form<T>(&self, queue: &BoundedQueue<T>) -> Option<Vec<T>> {
        let first = queue.pop_blocking()?;
        self.fill(queue, first, self.timeout, self.timeout, &mut || true)
    }

    /// Form one batch, waiting at most `first_wait` for the first item
    /// (a scheduler pick can race another worker to an emptied queue,
    /// so the first pop must not block forever) and holding the batch
    /// window open at most `window` — the shared runtime's entry point.
    /// A contended caller passes `window == 0` so a hot queue's
    /// coalescing never delays a cold queue's turn; an uncontended one
    /// passes a `slice` smaller than the window plus a `keep_open`
    /// re-check, so a window opened while the fleet was idle closes
    /// early when another queue becomes backlogged mid-window — without
    /// this, a single-worker fleet coalescing one model's trickle would
    /// sit out the full window while another model's deadlined request
    /// expired (the contended/uncontended decision is otherwise frozen
    /// at pick time).
    pub fn form_adaptive<T>(
        &self,
        queue: &BoundedQueue<T>,
        first_wait: Duration,
        window: Duration,
        slice: Duration,
        mut keep_open: impl FnMut() -> bool,
    ) -> Option<Vec<T>> {
        let first = queue.pop_wait(first_wait)?;
        self.fill(queue, first, window, slice, &mut keep_open)
    }

    /// Shared tail: grow `first` into a batch within `window`, shrink to
    /// a supported size, return leftovers to the queue front.
    fn fill<T>(
        &self,
        queue: &BoundedQueue<T>,
        first: T,
        window: Duration,
        slice: Duration,
        keep_open: &mut impl FnMut() -> bool,
    ) -> Option<Vec<T>> {
        let mut items = vec![first];
        let deadline = Instant::now() + window;
        while items.len() < self.max_batch {
            // Fast path: grab whatever is already waiting.
            let mut more = queue.drain_up_to(self.max_batch - items.len());
            if !more.is_empty() {
                items.append(&mut more);
                // A sustained burst must not extend the batch window: a
                // queue that refills as fast as we drain would otherwise
                // keep this loop in the fast path forever.  Check the
                // deadline before re-draining.
                if Instant::now() >= deadline {
                    break;
                }
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if !keep_open() {
                break; // another queue became backlogged — stop coalescing
            }
            let wait = (deadline - now).min(slice);
            match queue.pop_wait(wait) {
                Some(item) => items.push(item),
                // A closed, drained queue has nothing left to wait for;
                // otherwise a slice timeout loops back to re-check the
                // window and keep_open.
                None if queue.is_closed() => break,
                None => continue,
            }
        }
        let (batch, rest) = self.split(items);
        if !rest.is_empty() {
            queue.push_front_bulk(rest);
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max: usize) -> BatchPolicy {
        BatchPolicy::new(max, Duration::from_millis(5), &[1, 2, 4, 8])
    }

    #[test]
    fn fit_picks_largest_supported() {
        let p = policy(8);
        assert_eq!(p.fit(1), 1);
        assert_eq!(p.fit(3), 2);
        assert_eq!(p.fit(4), 4);
        assert_eq!(p.fit(7), 4);
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(100), 8);
    }

    #[test]
    fn fit_respects_max_batch() {
        let p = policy(2);
        assert_eq!(p.fit(8), 2);
    }

    #[test]
    fn one_is_always_supported() {
        let p = BatchPolicy::new(4, Duration::ZERO, &[4]);
        assert_eq!(p.fit(3), 1);
    }

    #[test]
    fn split_keeps_order() {
        let p = policy(8);
        let (batch, rest) = p.split(vec![1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(batch, vec![1, 2, 3, 4]);
        assert_eq!(rest, vec![5, 6, 7]);
    }

    #[test]
    fn form_collects_waiting_items() {
        let q = BoundedQueue::new(16);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let p = policy(8);
        let batch = p.form(&q).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]); // fit(5)=4
        assert_eq!(q.len(), 1); // leftover back in queue
        let batch2 = p.form(&q).unwrap();
        assert_eq!(batch2, vec![4]);
    }

    #[test]
    fn form_returns_none_on_closed_empty() {
        let q = BoundedQueue::<u32>::new(4);
        q.close();
        assert_eq!(policy(4).form(&q), None);
    }

    #[test]
    fn sustained_burst_cannot_extend_window_past_timeout() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // A producer refills the queue as fast as form() drains it; the
        // old fast-path `continue` never re-checked the deadline, so the
        // window stretched until max_batch filled.  With max_batch far
        // above what the window can collect, form() must still return
        // within (roughly) the timeout.
        let q = Arc::new(BoundedQueue::new(1024));
        let stop = Arc::new(AtomicBool::new(false));
        let producer = {
            let q = q.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    let _ = q.try_push(i);
                    i = i.wrapping_add(1);
                }
            })
        };
        let p = BatchPolicy::new(1_000_000, Duration::from_millis(30), &[1, 2, 4, 8]);
        let t0 = Instant::now();
        let batch = p.form(&q).unwrap();
        let elapsed = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        producer.join().unwrap();
        assert!(!batch.is_empty());
        assert!(
            elapsed < Duration::from_millis(500),
            "batch window stretched to {elapsed:?} under sustained load"
        );
    }

    #[test]
    fn form_adaptive_bounds_first_wait_window_and_keep_open() {
        // Empty queue: returns None after ~first_wait, never blocks.
        let q = BoundedQueue::<u32>::new(8);
        let p = policy(8);
        let first_wait = Duration::from_millis(10);
        let t0 = Instant::now();
        assert_eq!(
            p.form_adaptive(&q, first_wait, Duration::ZERO, Duration::ZERO, || true),
            None
        );
        assert!(t0.elapsed() < Duration::from_millis(200));
        // Zero window: takes what's there, no coalescing wait.
        for i in 0..3 {
            q.try_push(i).unwrap();
        }
        let t0 = Instant::now();
        let batch = p
            .form_adaptive(&q, first_wait, Duration::ZERO, Duration::ZERO, || true)
            .unwrap();
        assert!(!batch.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100));
        // keep_open() == false closes a long window at the next slice
        // instead of waiting it out.
        q.try_push(9).unwrap();
        let t0 = Instant::now();
        let batch = p
            .form_adaptive(
                &q,
                first_wait,
                Duration::from_secs(2),
                Duration::from_millis(1),
                || false,
            )
            .unwrap();
        assert_eq!(batch, vec![9]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn form_times_out_to_small_batch() {
        let q = BoundedQueue::new(4);
        q.try_push(9u32).unwrap();
        let t0 = Instant::now();
        let batch = policy(8).form(&q).unwrap();
        assert_eq!(batch, vec![9]);
        assert!(t0.elapsed() >= Duration::from_millis(4), "must wait the window");
    }
}
