//! Runtime worker threads: the fixed, process-wide worker fleet behind
//! the shared scheduler (DESIGN.md §4).
//!
//! A worker is no longer pinned to one (model, engine) pool.  Each
//! iteration it asks the scheduler for the next queue to serve
//! (deadline-urgent first, then weighted fair share), forms a batch
//! from *that* queue, and executes it on an engine replica from its
//! private, byte-bounded LRU cache.  XLA handles are not `Send`, so
//! replicas are still built inside the worker thread — the cache is
//! what makes switching models cheap and bounds resident weights
//! (`replica_cache_mb`).
//!
//! Policy duties on the request path (DESIGN.md §7) are unchanged:
//! before forming a batch the pending queue is stable-sorted by urgency
//! (priority, then deadline) and already-expired requests are shed with
//! a structured rejection; after each batch the observed execution time
//! feeds the generation's latency predictor and — on the quality queue
//! only — the per-request results fill the response cache.
//!
//! Memory duties (DESIGN.md §7.5) are unchanged: the batch is assembled
//! in place into a buffer leased from the *generation's* arena, the
//! engine reads it as a borrowed view, and reply extraction reads
//! borrowed output rows.  The lease returns on every exit path.
//!
//! Drain duties (DESIGN.md §8): an [`InflightGuard`] is taken *before*
//! the first pop of a batch, so a retiring generation's
//! `wait_drained` can never observe "queue empty" while a batch is
//! mid-flight.  Closed queues are still served while they hold residual
//! items — a reload drain answers everything on the old weights.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::engine::{self, Engine};
use crate::metrics::ledger::Ledger;
use crate::metrics::Histogram;
use crate::obs::{flag, ObsHub, Stage};
use crate::policy::{CachedResult, Urgency};
use crate::tensor::TensorView;
use crate::util::log::{suppressed_note, SHED_LOG};

use super::scheduler::{
    replica_bytes, InflightGuard, Pick, ReplicaCache, Scheduler, WorkSource,
    WorkerSlot,
};
use super::{Request, Response};

/// The reply sent for an admitted request whose deadline passed while it
/// waited in queue (tested against in examples and policy_props).
pub const DEADLINE_ERROR: &str = "deadline exceeded in queue";

/// How long a worker waits for the first item of a batch after a pick
/// (covers the race where another worker drained the picked queue).
const FIRST_POP_WAIT: Duration = Duration::from_millis(2);

/// Granularity at which an uncontended batch window re-checks whether
/// another queue became backlogged (bounds the cross-queue latency a
/// coalescing worker can add on a small fleet).
const WINDOW_SLICE: Duration = Duration::from_millis(2);

/// Idle housekeeping tick (dead-replica eviction between work).
const IDLE_TICK: Duration = Duration::from_millis(200);

/// What a runtime worker hands back at shutdown.
#[derive(Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub images: u64,
    /// Merged ledgers of every engine replica this worker built.
    pub ledger: Ledger,
    /// Total wall time spent building + warming replicas.
    pub compile_ms: f64,
    /// Replica-cache traffic: hits avoid a rebuild, misses pay one,
    /// evictions measure byte-budget pressure (`replica_cache_mb`).
    pub replica_hits: u64,
    pub replica_misses: u64,
    pub replica_evictions: u64,
}

/// Shared live counters (cheap to bump on the hot path).
#[derive(Default)]
pub struct SharedStats {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub images: AtomicU64,
    pub latency: Mutex<Histogram>,
    pub batch_sizes: Mutex<Histogram>,
    /// The tracing hub (DESIGN.md §10).  Lives here so the admission
    /// path, the workers, and the server planes — which all already
    /// share these stats — stamp spans against one epoch.
    pub obs: Arc<ObsHub>,
}

/// Everything one runtime worker thread needs.
pub struct RuntimeWorker {
    pub index: usize,
    pub scheduler: Arc<Scheduler>,
    pub stats: Arc<SharedStats>,
    /// Per-worker occupancy slots (index `index` is this worker's).
    pub slots: Arc<Vec<WorkerSlot>>,
    /// Byte budget for this worker's engine-replica LRU.
    pub replica_cache_bytes: usize,
}

pub fn spawn_runtime_worker(w: RuntimeWorker) -> JoinHandle<WorkerReport> {
    std::thread::Builder::new()
        .name(format!("zuluko-runtime-{}", w.index))
        .spawn(move || run_worker(w))
        .expect("spawn runtime worker")
}

fn run_worker(w: RuntimeWorker) -> WorkerReport {
    let mut cache: ReplicaCache<Box<dyn Engine>> =
        ReplicaCache::new(w.replica_cache_bytes);
    let mut ledger = Ledger::new();
    let mut batches = 0u64;
    let mut images = 0u64;
    let mut compile_ms = 0.0f64;
    let mut seen_epoch = w.scheduler.table_epoch();

    loop {
        match w.scheduler.next(IDLE_TICK) {
            Pick::Shutdown => break,
            Pick::Idle => {
                for dead in cache.evict_dead(|k| w.scheduler.is_live(k)) {
                    ledger.merge(dead.ledger());
                }
            }
            Pick::Prefetch { source } => {
                // Predictive warm-up: pre-build this queue's replica so
                // the next burst skips the cold build.  The cache check
                // is the dedup guard — a replica already present (this
                // worker served the queue, or a previous grant landed
                // here) makes the grant a no-op.
                if cache.get_quiet(&source.key).is_none() {
                    let t0 = Instant::now();
                    match build_replica(&source) {
                        Ok((mut eng, prewarmed)) => {
                            let warm = if prewarmed { Ok(()) } else { eng.warmup() };
                            match warm {
                                Ok(()) => {
                                    compile_ms += crate::util::ms(t0.elapsed());
                                    let bytes = replica_bytes(
                                        source.key.engine,
                                        &source.exec.manifest,
                                    );
                                    for old in
                                        cache.insert(source.key.clone(), eng, bytes)
                                    {
                                        ledger.merge(old.ledger());
                                    }
                                    source
                                        .exec
                                        .counters
                                        .prefetch_builds
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) => crate::warn!(
                                    "worker",
                                    "prefetch warm-up for {} failed: {e:#}",
                                    source.key
                                ),
                            }
                        }
                        Err(e) => crate::warn!(
                            "worker",
                            "prefetch build for {} failed: {e:#}",
                            source.key
                        ),
                    }
                }
            }
            Pick::Work { source, contended } => {
                // Inflight is marked before any pop so a concurrent
                // drain can never miss this batch.
                let _inflight = InflightGuard::new(source.clone(), w.scheduler.clone());
                let (b, i, busy) = serve_one(
                    &w,
                    &source,
                    contended,
                    &mut cache,
                    &mut compile_ms,
                    &mut ledger,
                );
                batches += b;
                images += i;
                let slot = &w.slots[w.index];
                slot.batches.fetch_add(b, Ordering::Relaxed);
                slot.images.fetch_add(i, Ordering::Relaxed);
                slot.busy_us.fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
                // Retired generations' replicas are dead weight in the
                // byte budget — evict them promptly, not just on idle.
                // Gated on the table epoch so steady-state serving pays
                // nothing for the rare-retire case.
                let epoch = w.scheduler.table_epoch();
                if epoch != seen_epoch {
                    seen_epoch = epoch;
                    for dead in cache.evict_dead(|k| w.scheduler.is_live(k)) {
                        ledger.merge(dead.ledger());
                    }
                }
            }
        }
    }

    for eng in cache.drain() {
        ledger.merge(eng.ledger());
    }
    WorkerReport {
        worker: w.index,
        batches,
        images,
        ledger,
        compile_ms,
        replica_hits: cache.hits,
        replica_misses: cache.misses,
        replica_evictions: cache.evictions,
    }
}

/// Construct one engine replica for `source`'s queue, preferring the
/// generation's in-memory [`crate::runtime::ReplicaSnapshot`] when one
/// is attached (pre-decoded weights, no artifact-directory reads).
/// Returns the engine plus whether the snapshot's warm-plan covers this
/// kind (`true` = the caller may skip `warmup()`).  Any snapshot-path
/// error falls back to a cold build — a snapshot is never load-bearing.
fn build_replica(source: &WorkSource) -> anyhow::Result<(Box<dyn Engine>, bool)> {
    let exec = &source.exec;
    let kind = source.key.engine;
    if let Some(snap) = &exec.snapshot {
        match engine::build_from_snapshot(kind, snap) {
            Ok(eng) => {
                exec.counters.snapshot_hits.fetch_add(1, Ordering::Relaxed);
                return Ok((eng, snap.warm_covers(kind)));
            }
            Err(e) => {
                exec.counters.snapshot_fallbacks.fetch_add(1, Ordering::Relaxed);
                crate::warn!(
                    "worker",
                    "snapshot build for {} failed ({e:#}); cold-building",
                    source.key
                );
            }
        }
    } else if exec.snapshots_on {
        exec.counters.snapshot_misses.fetch_add(1, Ordering::Relaxed);
    }
    Ok((engine::build(kind, &exec.manifest)?, false))
}

/// Borrow (or build + warm) the engine replica for `source`'s queue.
/// Replicas evicted for byte pressure fold their ledgers into the
/// worker's report instead of vanishing.
fn replica<'a>(
    cache: &'a mut ReplicaCache<Box<dyn Engine>>,
    source: &WorkSource,
    compile_ms: &mut f64,
    ledger: &mut Ledger,
) -> anyhow::Result<&'a mut Box<dyn Engine>> {
    if cache.get(&source.key).is_none() {
        let t0 = Instant::now();
        let (mut eng, prewarmed) = build_replica(source)?;
        if !prewarmed {
            eng.warmup()?;
        }
        *compile_ms += crate::util::ms(t0.elapsed());
        let bytes = replica_bytes(source.key.engine, &source.exec.manifest);
        for old in cache.insert(source.key.clone(), eng, bytes) {
            ledger.merge(old.ledger());
        }
    }
    // Quiet re-borrow: the hit/miss was already counted above — a
    // counting get here would report ~50% hits on a 100%-thrash cache.
    Ok(cache.get_quiet(&source.key).expect("replica just inserted"))
}

/// Serve one batch from `source`.  Returns (batches, images, busy)
/// where busy is the wall time spent *serving* — measured from batch
/// formation, so the coalescing window and the first-pop wait don't
/// count an idle fleet as busy.
fn serve_one(
    w: &RuntimeWorker,
    source: &Arc<WorkSource>,
    contended: bool,
    cache: &mut ReplicaCache<Box<dyn Engine>>,
    compile_ms: &mut f64,
    ledger: &mut Ledger,
) -> (u64, u64, Duration) {
    let queue = &source.queue;
    let exec = &source.exec;
    let model = &exec.model;

    // Deadline-aware ordering: most urgent work first.  Stable, so
    // plain FIFO traffic is untouched.
    queue.sort_pending_by_key(|r| Urgency::of(&r.slo, r.submitted));

    // Work-conserving batch window: when other queues are waiting, take
    // what is already here instead of holding the window open — and an
    // uncontended window is re-checked every slice so a queue that
    // becomes backlogged mid-window (a deadlined request on an
    // otherwise idle fleet) closes it early instead of waiting out the
    // full coalescing timeout.
    let window = if contended {
        Duration::ZERO
    } else {
        source.policy.timeout
    };
    let Some(mut reqs) = source.policy.form_adaptive(
        queue,
        FIRST_POP_WAIT,
        window,
        WINDOW_SLICE,
        || !w.scheduler.pending_elsewhere(&source.key),
    ) else {
        return (0, 0, Duration::ZERO); // raced empty, or closed + drained
    };
    let busy_from = Instant::now();
    let hub = &w.stats.obs;
    let dequeued_ns = hub.now_ns();
    for r in &mut reqs {
        r.span.set(Stage::Dequeued, dequeued_ns);
    }
    // The batcher's shrink-to-supported-size may have pushed leftovers
    // back to the queue front without passing the scheduler's submit
    // path — wake idle workers so a (possibly deadlined) leftover never
    // languishes behind this worker's inference.
    if !queue.is_empty() {
        w.scheduler.notify_all();
    }

    // Shed batch members whose deadline already passed — never silent.
    let now = Instant::now();
    let (expired, live): (Vec<Request>, Vec<Request>) = reqs
        .into_iter()
        .partition(|r| r.slo.expired(r.submitted, now));
    let n_expired = expired.len();
    for mut r in expired {
        exec.ctx.shed_expired.fetch_add(1, Ordering::Relaxed);
        r.span.flags |= flag::SHED_EXPIRED;
        let mut resp = Response::shed_expired(r.id, DEADLINE_ERROR);
        resp.model = model.clone();
        resp.span = Some(r.span);
        r.reply.send(resp);
    }
    if n_expired > 0 {
        // Token-bucket limited: a saturated queue sheds in bulk, and an
        // unthrottled warn per batch would make the logger part of the
        // overload.
        if let Some(sup) = SHED_LOG.allow() {
            crate::warn!(
                "worker",
                "shed {n_expired} expired request(s) on '{model}'{}",
                suppressed_note(sup)
            );
        }
    }
    if live.is_empty() {
        w.scheduler.charge(&source.key, n_expired.max(1));
        return (0, 0, busy_from.elapsed());
    }
    // Shedding may leave a batch size without an artifact; re-split and
    // return the tail to the queue front.
    let (mut live, leftover) = source.policy.split(live);
    if !leftover.is_empty() {
        queue.push_front_bulk(leftover);
        // The leftovers bypassed the scheduler's submit path — wake
        // idle workers so they never languish while this worker is
        // busy with the batch it kept.
        w.scheduler.notify_all();
    }

    let formed_at = Instant::now();
    let formed_ns = hub.now_ns();
    for r in &mut live {
        r.span.set(Stage::BatchFormed, formed_ns);
    }
    let bsize = live.len();
    let per = live[0].image.len();
    let row_shape = live[0].image.shape().to_vec();
    if live.iter().any(|r| r.image.shape() != &row_shape[..]) {
        fail_batch(model, &live, "batch shape mismatch");
        w.scheduler.charge(&source.key, bsize);
        return (0, 0, busy_from.elapsed());
    }

    // In-place batching: lease a batch buffer from this generation's
    // arena and copy each request's pooled pixels straight into their
    // slot — the only copy between socket and engine.
    let mut bshape = Vec::with_capacity(row_shape.len() + 1);
    bshape.push(bsize);
    bshape.extend_from_slice(&row_shape);
    let mut bbuf = exec.arena.lease(bsize * per);
    for (slot, r) in live.iter().enumerate() {
        bbuf[slot * per..(slot + 1) * per].copy_from_slice(r.image.data());
    }

    let eng = match replica(cache, source, compile_ms, ledger) {
        Ok(e) => e,
        Err(e) => {
            drop(bbuf);
            fail_batch(model, &live, &format!("engine build: {e:#}"));
            w.scheduler.charge(&source.key, bsize);
            return (0, 0, busy_from.elapsed());
        }
    };
    let infer_start_ns = hub.now_ns();
    let t0 = Instant::now();
    let out = eng.infer_view(TensorView::new(&bshape, &bbuf));
    let exec_ms = crate::util::ms(t0.elapsed());
    let infer_done_ns = hub.now_ns();
    drop(bbuf); // back to the arena before reply fan-out
    for r in &mut live {
        r.span.set(Stage::InferStart, infer_start_ns);
        r.span.set(Stage::InferDone, infer_done_ns);
    }

    let mut served = (0u64, 0u64);
    match out {
        Ok(probs) if probs.shape().first() == Some(&bsize) => {
            served = (1, bsize as u64);
            exec.ctx.predictor.record(source.key.engine, bsize, exec_ms);
            w.stats.batch_sizes.lock().unwrap().record_ms(bsize as f64);
            // Per-model stage attribution: one lock for the whole batch,
            // off the per-request path (DESIGN.md §10).
            exec.stage_hist.record_batch(live.iter().map(|r| r.span));
            let pv = probs.view();
            for (slot, req) in live.into_iter().enumerate() {
                // Borrowed output row: argmax/top-5 read the batch
                // tensor in place (no unstack copy).
                let row = pv.row(slot);
                let total_ms = crate::util::ms(req.submitted.elapsed());
                let queue_ms = crate::util::ms(formed_at.duration_since(req.submitted));
                let top1 = row.argmax();
                let top5 = row.topk(5);
                if source.fill_cache {
                    // Fill under the content key, and alias under the
                    // wire key so the next identical raw request skips
                    // decode.
                    let cached = CachedResult {
                        top1,
                        top5: top5.clone(),
                    };
                    for key in req.cache_key.iter().chain(req.wire_key.iter()) {
                        exec.ctx.cache.put(*key, cached.clone());
                    }
                }
                req.reply.send(Response {
                    id: req.id,
                    top1,
                    top5,
                    queue_ms,
                    exec_ms,
                    total_ms,
                    batch_size: bsize,
                    worker: w.index,
                    engine: source.key.engine.as_str(),
                    model: model.clone(),
                    cached: false,
                    kind: "",
                    error: None,
                    span: Some(req.span),
                });
                w.stats.completed.fetch_add(1, Ordering::Relaxed);
                w.stats.images.fetch_add(1, Ordering::Relaxed);
                exec.counters.completed.fetch_add(1, Ordering::Relaxed);
                exec.counters.images.fetch_add(1, Ordering::Relaxed);
                w.stats.latency.lock().unwrap().record_ms(total_ms);
            }
        }
        Ok(probs) => fail_batch(
            model,
            &live,
            &format!(
                "infer: engine returned shape {:?} for batch {bsize}",
                probs.shape()
            ),
        ),
        Err(e) => fail_batch(model, &live, &format!("infer: {e}")),
    }
    w.scheduler.charge(&source.key, bsize);
    (served.0, served.1, busy_from.elapsed())
}

fn fail_batch(model: &Arc<str>, reqs: &[Request], msg: &str) {
    for r in reqs {
        let mut resp = Response::error(r.id, msg);
        resp.model = model.clone();
        resp.span = Some(r.span);
        r.reply.send(resp);
    }
}
