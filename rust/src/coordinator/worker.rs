//! Worker threads: each owns an engine replica (XLA handles are not Send,
//! so the engine is built *inside* the thread) and drains its queue via
//! the dynamic batcher.

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engine::{self, EngineKind};
use crate::metrics::ledger::Ledger;
use crate::metrics::Histogram;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

use super::batcher::BatchPolicy;
use super::queue::BoundedQueue;
use super::{Request, Response};

/// What a worker hands back at shutdown.
#[derive(Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub images: u64,
    pub ledger: Ledger,
    pub compile_ms: f64,
}

/// Shared live counters (cheap to bump on the hot path).
#[derive(Default)]
pub struct SharedStats {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub images: AtomicU64,
    pub latency: Mutex<Histogram>,
    pub batch_sizes: Mutex<Histogram>,
}

pub fn spawn_worker(
    worker: usize,
    kind: EngineKind,
    manifest: Manifest,
    queue: Arc<BoundedQueue<Request>>,
    policy: BatchPolicy,
    stats: Arc<SharedStats>,
    ready: mpsc::Sender<Result<()>>,
) -> JoinHandle<WorkerReport> {
    std::thread::Builder::new()
        .name(format!("zuluko-worker-{worker}"))
        .spawn(move || {
            // Build + warm the engine before signalling readiness so the
            // coordinator's callers never measure compilation.
            let mut eng = match engine::build(kind, &manifest) {
                Ok(mut e) => match e.warmup() {
                    Ok(()) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(err) => {
                        let _ = ready.send(Err(err));
                        return WorkerReport {
                            worker,
                            batches: 0,
                            images: 0,
                            ledger: Ledger::new(),
                            compile_ms: 0.0,
                        };
                    }
                },
                Err(err) => {
                    let _ = ready.send(Err(err));
                    return WorkerReport {
                        worker,
                        batches: 0,
                        images: 0,
                        ledger: Ledger::new(),
                        compile_ms: 0.0,
                    };
                }
            };

            let mut batches = 0u64;
            let mut images = 0u64;

            while let Some(reqs) = policy.form(&queue) {
                let formed_at = Instant::now();
                let refs: Vec<&Tensor> = reqs.iter().map(|r| &r.image).collect();
                let batch = match Tensor::stack(&refs) {
                    Ok(b) => b,
                    Err(e) => {
                        fail_batch(&reqs, &format!("stack: {e}"));
                        continue;
                    }
                };
                let t0 = Instant::now();
                let out = eng.infer(&batch);
                let exec_ms = crate::util::ms(t0.elapsed());

                match out.and_then(|o| o.unstack().map_err(Into::into)) {
                    Ok(rows) => {
                        let bsize = reqs.len();
                        batches += 1;
                        images += bsize as u64;
                        stats
                            .batch_sizes
                            .lock()
                            .unwrap()
                            .record_ms(bsize as f64);
                        for (req, row) in reqs.into_iter().zip(rows) {
                            let total_ms =
                                crate::util::ms(req.submitted.elapsed());
                            let queue_ms = crate::util::ms(
                                formed_at.duration_since(req.submitted),
                            );
                            let _ = req.reply.send(Response {
                                id: req.id,
                                top1: row.argmax(),
                                top5: row.topk(5),
                                queue_ms,
                                exec_ms,
                                total_ms,
                                batch_size: bsize,
                                worker,
                                error: None,
                            });
                            stats.completed.fetch_add(1, Ordering::Relaxed);
                            stats.images.fetch_add(1, Ordering::Relaxed);
                            stats
                                .latency
                                .lock()
                                .unwrap()
                                .record_ms(total_ms);
                        }
                    }
                    Err(e) => fail_batch_owned(reqs, &format!("infer: {e}")),
                }
            }

            let compile_ms = 0.0; // engines expose this via acl; generic 0
            WorkerReport {
                worker,
                batches,
                images,
                ledger: eng.ledger().clone(),
                compile_ms,
            }
        })
        .expect("spawn worker")
}

fn fail_batch(reqs: &[Request], msg: &str) {
    for r in reqs {
        let _ = r.reply.send(Response::error(r.id, msg));
    }
}

fn fail_batch_owned(reqs: Vec<Request>, msg: &str) {
    for r in &reqs {
        let _ = r.reply.send(Response::error(r.id, msg));
    }
}
