//! Worker threads: each owns an engine replica (XLA handles are not Send,
//! so the engine is built *inside* the thread) and drains its queue via
//! the dynamic batcher.
//!
//! Policy duties on the request path (DESIGN.md §7): before forming a
//! batch the pending queue is stable-sorted by urgency (priority, then
//! deadline) and already-expired requests are shed with a structured
//! rejection instead of burning engine time; after each batch the
//! observed execution time feeds the shared latency predictor and —
//! on the quality pool only — the per-request results fill the
//! response cache.
//!
//! Memory duties (DESIGN.md §"Memory ownership on the hot path"): the
//! batch is assembled *in place* into a buffer leased from the tensor
//! arena — each request's pooled pixels are copied straight into their
//! batch slot (no `Tensor::stack` allocation) — the engine reads it as
//! a borrowed view, and reply extraction reads borrowed output rows
//! (no `unstack` copies).  The lease returns to the arena on every
//! exit path, including errors, because return is `Drop`.
//!
//! Registry duties (DESIGN.md §8): a worker belongs to one model
//! generation.  Its queue, arena, and policy ctx are that generation's;
//! every reply carries the model name so isolation is observable on the
//! wire; per-model counters (shared across the model's generations) are
//! bumped alongside the process-wide aggregates.

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engine::{self, EngineKind};
use crate::metrics::ledger::Ledger;
use crate::metrics::Histogram;
use crate::policy::{CachedResult, PolicyCtx, Urgency};
use crate::registry::ModelCounters;
use crate::runtime::Manifest;
use crate::tensor::{TensorPool, TensorView};

use super::batcher::BatchPolicy;
use super::queue::BoundedQueue;
use super::{Request, Response};

/// The reply sent for an admitted request whose deadline passed while it
/// waited in queue (tested against in examples and policy_props).
pub const DEADLINE_ERROR: &str = "deadline exceeded in queue";

/// What a worker hands back at shutdown.
#[derive(Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub images: u64,
    pub ledger: Ledger,
    pub compile_ms: f64,
}

/// Shared live counters (cheap to bump on the hot path).
#[derive(Default)]
pub struct SharedStats {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub images: AtomicU64,
    pub latency: Mutex<Histogram>,
    pub batch_sizes: Mutex<Histogram>,
}

/// Everything one worker thread needs — bundled so a seat is one value,
/// not a dozen positional arguments.
pub struct WorkerSeat {
    /// Process-unique worker index (spans pools within a generation).
    pub index: usize,
    pub kind: EngineKind,
    /// Model this worker's generation serves (echoed in every reply).
    pub model: Arc<str>,
    pub manifest: Manifest,
    pub queue: Arc<BoundedQueue<Request>>,
    pub policy: BatchPolicy,
    /// Process-wide aggregates.
    pub stats: Arc<SharedStats>,
    /// Per-model counters (survive hot reloads).
    pub counters: Arc<ModelCounters>,
    /// This generation's policy state (predictor + response cache).
    pub ctx: Arc<PolicyCtx>,
    pub arena: TensorPool,
    /// Only the quality pool fills the response cache: caching an int8
    /// result would let later fp32-entitled requests hit it (Fig 4
    /// accuracy loss through the back door).
    pub fill_cache: bool,
}

pub fn spawn_worker(
    seat: WorkerSeat,
    ready: mpsc::Sender<Result<()>>,
) -> JoinHandle<WorkerReport> {
    std::thread::Builder::new()
        .name(format!("zuluko-worker-{}-{}", seat.model, seat.index))
        .spawn(move || {
            let WorkerSeat {
                index: worker,
                kind,
                model,
                manifest,
                queue,
                policy,
                stats,
                counters,
                ctx,
                arena: pool,
                fill_cache,
            } = seat;
            // Build + warm the engine before signalling readiness so the
            // coordinator's callers never measure compilation.
            let mut eng = match engine::build(kind, &manifest) {
                Ok(mut e) => match e.warmup() {
                    Ok(()) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(err) => {
                        let _ = ready.send(Err(err));
                        return WorkerReport {
                            worker,
                            batches: 0,
                            images: 0,
                            ledger: Ledger::new(),
                            compile_ms: 0.0,
                        };
                    }
                },
                Err(err) => {
                    let _ = ready.send(Err(err));
                    return WorkerReport {
                        worker,
                        batches: 0,
                        images: 0,
                        ledger: Ledger::new(),
                        compile_ms: 0.0,
                    };
                }
            };

            let mut batches = 0u64;
            let mut images = 0u64;

            loop {
                // Deadline-aware ordering: most urgent work first.
                // Stable, so plain FIFO traffic is untouched.
                queue.sort_pending_by_key(|r| Urgency::of(&r.slo, r.submitted));

                let Some(reqs) = policy.form(&queue) else { break };

                // Shed batch members whose deadline already passed —
                // running them would waste engine time on a reply the
                // client has given up on.  Never silent: each shed
                // request gets a structured error response.
                let now = Instant::now();
                let (expired, live): (Vec<Request>, Vec<Request>) = reqs
                    .into_iter()
                    .partition(|r| r.slo.expired(r.submitted, now));
                for r in &expired {
                    ctx.shed_expired.fetch_add(1, Ordering::Relaxed);
                    let mut resp = Response::shed_expired(r.id, DEADLINE_ERROR);
                    resp.model = model.clone();
                    let _ = r.reply.send(resp);
                }
                if live.is_empty() {
                    continue;
                }
                // Shedding may leave a batch size without an artifact;
                // re-split and return the tail to the queue front.
                let (live, leftover) = policy.split(live);
                if !leftover.is_empty() {
                    queue.push_front_bulk(leftover);
                }

                let formed_at = Instant::now();
                let bsize = live.len();
                let per = live[0].image.len();
                let row_shape = live[0].image.shape().to_vec();
                if live.iter().any(|r| r.image.shape() != &row_shape[..]) {
                    fail_batch(&model, &live, "batch shape mismatch");
                    continue;
                }
                // In-place batching: lease a batch buffer from the arena
                // and copy each request's pooled pixels straight into
                // their slot — the only copy between socket and engine.
                let mut bshape = Vec::with_capacity(row_shape.len() + 1);
                bshape.push(bsize);
                bshape.extend_from_slice(&row_shape);
                let mut bbuf = pool.lease(bsize * per);
                for (slot, r) in live.iter().enumerate() {
                    bbuf[slot * per..(slot + 1) * per]
                        .copy_from_slice(r.image.data());
                }
                let t0 = Instant::now();
                let out = eng.infer_view(TensorView::new(&bshape, &bbuf));
                let exec_ms = crate::util::ms(t0.elapsed());
                drop(bbuf); // back to the arena before reply fan-out

                match out {
                    Ok(probs) if probs.shape().first() == Some(&bsize) => {
                        batches += 1;
                        images += bsize as u64;
                        ctx.predictor.record(kind, bsize, exec_ms);
                        stats
                            .batch_sizes
                            .lock()
                            .unwrap()
                            .record_ms(bsize as f64);
                        let pv = probs.view();
                        for (slot, req) in live.into_iter().enumerate() {
                            // Borrowed output row: argmax/top-5 read the
                            // batch tensor in place (no unstack copy).
                            let row = pv.row(slot);
                            let total_ms =
                                crate::util::ms(req.submitted.elapsed());
                            let queue_ms = crate::util::ms(
                                formed_at.duration_since(req.submitted),
                            );
                            let top1 = row.argmax();
                            let top5 = row.topk(5);
                            if fill_cache {
                                // Fill under the content key, and alias
                                // under the wire key so the next
                                // identical raw request skips decode.
                                let cached = CachedResult {
                                    top1,
                                    top5: top5.clone(),
                                };
                                for key in
                                    req.cache_key.iter().chain(req.wire_key.iter())
                                {
                                    ctx.cache.put(*key, cached.clone());
                                }
                            }
                            let _ = req.reply.send(Response {
                                id: req.id,
                                top1,
                                top5,
                                queue_ms,
                                exec_ms,
                                total_ms,
                                batch_size: bsize,
                                worker,
                                engine: kind.as_str(),
                                model: model.clone(),
                                cached: false,
                                kind: "",
                                error: None,
                            });
                            stats.completed.fetch_add(1, Ordering::Relaxed);
                            stats.images.fetch_add(1, Ordering::Relaxed);
                            counters.completed.fetch_add(1, Ordering::Relaxed);
                            counters.images.fetch_add(1, Ordering::Relaxed);
                            stats
                                .latency
                                .lock()
                                .unwrap()
                                .record_ms(total_ms);
                        }
                    }
                    Ok(probs) => fail_batch(
                        &model,
                        &live,
                        &format!(
                            "infer: engine returned shape {:?} for batch {bsize}",
                            probs.shape()
                        ),
                    ),
                    Err(e) => fail_batch(&model, &live, &format!("infer: {e}")),
                }
            }

            let compile_ms = 0.0; // engines expose this via acl; generic 0
            WorkerReport {
                worker,
                batches,
                images,
                ledger: eng.ledger().clone(),
                compile_ms,
            }
        })
        .expect("spawn worker")
}

fn fail_batch(model: &Arc<str>, reqs: &[Request], msg: &str) {
    for r in reqs {
        let mut resp = Response::error(r.id, msg);
        resp.model = model.clone();
        let _ = r.reply.send(resp);
    }
}
