//! PJRT runtime: load AOT artifacts, compile once, execute on the request
//! path.  Wraps the `xla` crate (PJRT C API, CPU plugin) following the
//! pattern in /opt/xla-example/load_hlo.
//!
//! Key decisions:
//! * **HLO text interchange** — `HloModuleProto::from_text_file` (jax >=0.5
//!   emits 64-bit ids the 0.5.1 proto parser rejects; text re-assigns ids).
//! * **Compile-once cache** — executables are compiled lazily per artifact
//!   path and cached for the process lifetime (`ExeCache`).
//! * **Not Send** — XLA objects stay on the thread that created them; each
//!   engine replica owns its own `Runtime` (see coordinator::worker).

pub mod manifest;
pub mod snapshot;
pub mod weights;

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

pub use manifest::{Manifest, OpEntry, StageEntry};
pub use snapshot::{artifact_content_hash, ReplicaSnapshot};
pub use weights::WeightStore;

/// A PJRT CPU client plus a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative time spent in `compile` (startup cost accounting).
    compile_time: RefCell<Duration>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: RefCell::new(BTreeMap::new()),
            compile_time: RefCell::new(Duration::ZERO),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        *self.compile_time.borrow_mut() += t0.elapsed();
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Total time spent compiling so far (reported at startup).
    pub fn compile_time(&self) -> Duration {
        *self.compile_time.borrow()
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Execute a compiled artifact on literals; returns output + wall time.
///
/// Artifacts are lowered with `return_tuple=True`, so the single output
/// arrives as a 1-tuple — unwrapped here.
pub fn run_timed(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::Literal],
) -> Result<(xla::Literal, Duration)> {
    let t0 = Instant::now();
    let mut outs = exe.execute::<&xla::Literal>(args).context("execute")?;
    let lit = outs
        .pop()
        .and_then(|mut v| v.pop())
        .context("empty execute result")?
        .to_literal_sync()
        .context("to_literal_sync")?;
    let out = lit.to_tuple1().context("untuple")?;
    Ok((out, t0.elapsed()))
}

/// f32 NHWC tensor -> literal.
///
/// §Perf iteration L3-1: the original implementation byte-copied through
/// `iter().flat_map(to_le_bytes).collect()` (one element at a time, a
/// fresh Vec<u8> per request, ~620 KB for the input image).  x86-64 and
/// every target we run on is little-endian, so the f32 slice *is* the
/// byte layout XLA wants — reinterpret it in place and skip the copy.
pub fn literal_from_tensor(t: &Tensor) -> Result<xla::Literal> {
    literal_from_slice(t.shape(), t.data())
}

/// Borrowed-slice variant: builds the input literal straight from a
/// pooled batch buffer / tensor view, so the serving path never
/// round-trips through an owned `Tensor` to reach the engine.
pub fn literal_from_slice(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    // Safety: f32 has no invalid bit patterns as bytes; alignment of u8 is
    // 1; length is exact.  Little-endian layout is asserted at compile
    // time below for portability honesty.
    #[cfg(not(target_endian = "little"))]
    compile_error!("literal_from_slice assumes little-endian f32 layout");
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )
    .context("literal_from_slice")
}

/// literal (f32 array of any rank) -> tensor.
pub fn tensor_from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().context("array_shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec::<f32>().context("literal to_vec")?;
    Tensor::new(&dims, data)
}
