//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the build-time Python world
//! and the runtime Rust world: parameter tables (with byte offsets into
//! weights.bin), the ACL stage lists, the baseline op graph, quantization
//! scales, and the golden-output index.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One fp32 parameter tensor's slot in weights.bin.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 *elements* from the start of weights.bin.
    pub offset: usize,
    pub nelems: usize,
}

/// One int8 parameter tensor's slot in weights_q8.bin.
#[derive(Debug, Clone)]
pub struct ParamQ8Entry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in bytes (i8 elements) from the start of weights_q8.bin.
    pub offset: usize,
    pub nelems: usize,
    pub scale: f64,
}

/// One fused ACL stage (serving or probe granularity).
#[derive(Debug, Clone)]
pub struct StageEntry {
    pub index: usize,
    pub name: String,
    pub params: Vec<String>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    /// Fig 3 group ("group1"/"group2") — probe stages only.
    pub group: Option<String>,
    /// batch size -> artifact relpath.
    pub artifacts: BTreeMap<usize, String>,
}

/// One primitive op of the baseline (or quantized) graph.
#[derive(Debug, Clone)]
pub struct OpEntry {
    pub index: usize,
    pub name: String,
    pub kind: String,
    pub group: String,
    pub inputs: Vec<String>,
    pub params: Vec<String>,
    pub in_shapes: Vec<Vec<usize>>,
    pub in_dtypes: Vec<String>,
    pub out_shape: Vec<usize>,
    pub out_dtype: String,
    pub artifact: String,
}

/// Golden-output index for integration tests.
#[derive(Debug, Clone)]
pub struct Golden {
    pub input: String,
    pub probs: String,
    pub probs_q8: String,
    pub stages: Vec<String>,
    pub top1: usize,
    pub top1_q8: usize,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub model: String,
    pub input_hw: usize,
    pub input_channels: usize,
    pub num_classes: usize,
    pub attenuation: f64,
    pub batch_sizes: Vec<usize>,
    pub params: Vec<ParamEntry>,
    pub params_q8: Vec<ParamQ8Entry>,
    pub scales: BTreeMap<String, f64>,
    pub stages: Vec<StageEntry>,
    pub probe_stages: Vec<StageEntry>,
    /// batch size -> fully-fused artifact relpath.
    pub full: BTreeMap<usize, String>,
    pub ops: Vec<OpEntry>,
    pub quant_ops: Vec<OpEntry>,
    pub golden: Golden,
}

fn parse_stage(j: &Json) -> Result<StageEntry> {
    let mut artifacts = BTreeMap::new();
    if let Some(m) = j.req("artifacts")?.as_obj() {
        for (k, v) in m {
            let b: usize = k.parse().context("artifact batch key")?;
            artifacts.insert(
                b,
                v.as_str().context("artifact path")?.to_string(),
            );
        }
    }
    Ok(StageEntry {
        index: j.usize_of("index")?,
        name: j.str_of("name")?.to_string(),
        params: string_vec(j.req("params")?)?,
        in_shape: j.shape_of("in_shape")?,
        out_shape: j.shape_of("out_shape")?,
        group: j
            .get("group")
            .and_then(|g| g.as_str())
            .map(|s| s.to_string()),
        artifacts,
    })
}

fn parse_op(j: &Json) -> Result<OpEntry> {
    let in_shapes = j
        .req("in_shapes")?
        .as_arr()
        .context("in_shapes")?
        .iter()
        .map(|s| {
            s.as_arr()
                .context("in_shape")
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        })
        .collect::<Result<Vec<Vec<usize>>>>()?;
    Ok(OpEntry {
        index: j.usize_of("index")?,
        name: j.str_of("name")?.to_string(),
        kind: j.str_of("kind")?.to_string(),
        group: j.str_of("group")?.to_string(),
        inputs: string_vec(j.req("inputs")?)?,
        params: string_vec(j.req("params")?)?,
        in_shapes,
        in_dtypes: string_vec(j.req("in_dtypes")?)?,
        out_shape: j.shape_of("out_shape")?,
        out_dtype: j.str_of("out_dtype")?.to_string(),
        artifact: j.str_of("artifact")?.to_string(),
    })
}

fn string_vec(j: &Json) -> Result<Vec<String>> {
    j.as_arr()
        .context("expected array of strings")?
        .iter()
        .map(|v| {
            v.as_str()
                .map(|s| s.to_string())
                .context("expected string")
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, root)
    }

    /// Parse and validate manifest JSON text with artifact paths resolved
    /// against `root`.  Split out of [`Manifest::load`] so a replica
    /// snapshot can embed the manifest text and rebuild the typed view
    /// without re-reading `manifest.json` (runtime::snapshot).
    pub fn parse(text: &str, root: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let params = j
            .req("params")?
            .as_arr()
            .context("params")?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.str_of("name")?.to_string(),
                    shape: p.shape_of("shape")?,
                    offset: p.usize_of("offset")?,
                    nelems: p.usize_of("nelems")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let params_q8 = j
            .req("params_q8")?
            .as_arr()
            .context("params_q8")?
            .iter()
            .map(|p| {
                Ok(ParamQ8Entry {
                    name: p.str_of("name")?.to_string(),
                    shape: p.shape_of("shape")?,
                    offset: p.usize_of("offset")?,
                    nelems: p.usize_of("nelems")?,
                    scale: p.f64_of("scale")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut scales = BTreeMap::new();
        if let Some(m) = j.req("scales")?.as_obj() {
            for (k, v) in m {
                scales.insert(k.clone(), v.as_f64().context("scale")?);
            }
        }

        let stages = j
            .req("stages")?
            .as_arr()
            .context("stages")?
            .iter()
            .map(parse_stage)
            .collect::<Result<Vec<_>>>()?;
        let probe_stages = j
            .req("probe_stages")?
            .as_arr()
            .context("probe_stages")?
            .iter()
            .map(parse_stage)
            .collect::<Result<Vec<_>>>()?;

        let mut full = BTreeMap::new();
        if let Some(m) = j.req("full")?.as_obj() {
            for (k, v) in m {
                full.insert(
                    k.parse::<usize>().context("full batch key")?,
                    v.as_str().context("full path")?.to_string(),
                );
            }
        }

        let ops = j
            .req("ops")?
            .as_arr()
            .context("ops")?
            .iter()
            .map(parse_op)
            .collect::<Result<Vec<_>>>()?;
        let quant_ops = j
            .req("quant_ops")?
            .as_arr()
            .context("quant_ops")?
            .iter()
            .map(parse_op)
            .collect::<Result<Vec<_>>>()?;

        let g = j.req("golden")?;
        let golden = Golden {
            input: g.str_of("input")?.to_string(),
            probs: g.str_of("probs")?.to_string(),
            probs_q8: g.str_of("probs_q8")?.to_string(),
            stages: string_vec(g.req("stages")?)?,
            top1: g.usize_of("top1")?,
            top1_q8: g.usize_of("top1_q8")?,
        };

        let m = Manifest {
            root: root.to_path_buf(),
            model: j.str_of("model")?.to_string(),
            input_hw: j.usize_of("input_hw")?,
            input_channels: j.usize_of("input_channels")?,
            num_classes: j.usize_of("num_classes")?,
            attenuation: j.f64_of("attenuation")?,
            batch_sizes: j.shape_of("batch_sizes")?,
            params,
            params_q8,
            scales,
            stages,
            probe_stages,
            full,
            ops,
            quant_ops,
            golden,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural sanity checks (fail fast at startup, not mid-request).
    fn validate(&self) -> Result<()> {
        if self.stages.is_empty() {
            bail!("manifest has no stages");
        }
        // Stage chain shapes must line up.
        for w in self.stages.windows(2) {
            if w[0].out_shape != w[1].in_shape {
                bail!(
                    "stage {} out {:?} != stage {} in {:?}",
                    w[0].name,
                    w[0].out_shape,
                    w[1].name,
                    w[1].in_shape
                );
            }
        }
        // Params referenced by stages/ops must exist in a table.
        let known: std::collections::BTreeSet<&str> = self
            .params
            .iter()
            .map(|p| p.name.as_str())
            .chain(self.params_q8.iter().map(|p| p.name.as_str()))
            .collect();
        for s in self.stages.iter().chain(&self.probe_stages) {
            for p in &s.params {
                if !known.contains(p.as_str()) {
                    bail!("stage {} references unknown param {}", s.name, p);
                }
            }
        }
        for o in self.ops.iter().chain(&self.quant_ops) {
            for p in &o.params {
                if !known.contains(p.as_str()) {
                    bail!("op {} references unknown param {}", o.name, p);
                }
            }
        }
        // Op graph must be topologically ordered (producers before users).
        for ops in [&self.ops, &self.quant_ops] {
            let mut seen = std::collections::BTreeSet::new();
            seen.insert("input".to_string());
            for o in ops.iter() {
                for i in &o.inputs {
                    if !seen.contains(i) {
                        bail!("op {} uses {} before it is produced", o.name, i);
                    }
                }
                seen.insert(o.name.clone());
            }
        }
        Ok(())
    }

    /// Absolute path of an artifact relpath.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn param(&self, name: &str) -> Result<&ParamEntry> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("unknown param {name}"))
    }

    pub fn param_q8(&self, name: &str) -> Result<&ParamQ8Entry> {
        self.params_q8
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("unknown q8 param {name}"))
    }

    /// Largest batch size with a fused artifact <= `n` (batcher helper).
    pub fn best_batch(&self, n: usize) -> usize {
        self.batch_sizes
            .iter()
            .copied()
            .filter(|&b| b <= n.max(1))
            .max()
            .unwrap_or(1)
    }
}
