//! AOT replica snapshots — cold-start as load-and-validate, not rebuild.
//!
//! A `.zsnap` file sits next to `manifest.json` and caches everything a
//! replica build would otherwise recompute from the artifact directory:
//! the manifest text (re-parsed, not re-read), the decoded f32/q8 weight
//! buffers in engine-ready layout, input/arena sizing, and a warm-plan of
//! engine kinds that were probe-warmed when the snapshot was captured.
//! `engine::build_from_snapshot` consumes it to skip filesystem reads,
//! weight decoding, and (when the warm-plan covers the kind) the warm-up
//! inference.
//!
//! Trust model — a snapshot is an *optimization*, never an authority:
//!
//! * **Versioned.** Magic + format version up front; any skew is a clean
//!   load error, never a misparse.
//! * **Checksummed.** A trailing FNV-1a-64 over header+payload catches
//!   truncation and bit-flips before any field is trusted.
//! * **Content-addressed.** The header stores the FNV hash of
//!   manifest.json + weights.bin + weights_q8.bin at capture time; the
//!   loader recomputes it from the live artifacts and refuses on any
//!   mismatch — a stale snapshot self-invalidates, so it can never serve
//!   weights that don't match the manifest on disk.
//! * **Fail-open to cold build.** Every failure above surfaces as
//!   `Err`, and every caller falls back to the existing cold build path
//!   (`engine::build`) — corruption degrades startup latency, never
//!   correctness (proven adversarially in tests/snapshot_props.rs).
//!
//! Writes are atomic: encode to `replica.zsnap.tmp`, then rename — a
//! concurrent reader sees either the old snapshot or the new one, never
//! a torn file.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::engine::EngineKind;
use crate::policy::bytes_key_parts;

use super::manifest::Manifest;

/// File name, next to manifest.json in the artifact directory.
pub const SNAPSHOT_FILE: &str = "replica.zsnap";

/// Format version; bump on any layout change.  Loads of other versions
/// fail cleanly (tested: version-skew → cold-build fallback).
pub const SNAPSHOT_VERSION: u32 = 1;

/// 8-byte magic. The embedded `\r\n\x1a` bytes catch text-mode mangling
/// the same way the PNG magic does.
const MAGIC: [u8; 8] = *b"ZSNP\r\n\x1a\0";

/// Warmed replica state, reconstructable without touching weights.bin.
pub struct ReplicaSnapshot {
    /// Hash of the artifacts this snapshot was captured from (see
    /// [`artifact_content_hash`]); the load path recomputes and compares.
    pub content_hash: u64,
    /// Parsed manifest (from the embedded text, rooted at the live
    /// artifact directory so HLO artifact relpaths still resolve).
    pub manifest: Manifest,
    /// The exact manifest.json text the snapshot embeds (what
    /// `manifest` was parsed from).
    pub manifest_text: String,
    /// Decoded fp32 weight buffers, keyed by param name.
    pub f32_bufs: BTreeMap<String, Vec<f32>>,
    /// Raw int8 weight buffers, keyed by param name (empty when the
    /// model ships no weights_q8.bin).
    pub q8_bufs: BTreeMap<String, Vec<u8>>,
    /// Input/arena sizing captured for cross-checks against the manifest.
    pub input_hw: usize,
    pub num_classes: usize,
    pub batch_sizes: Vec<usize>,
    /// Engine kinds that were probe-warmed when this snapshot was
    /// captured; builds for these kinds may skip `warmup()`.
    pub warm_plan: Vec<EngineKind>,
}

/// FNV-1a-64 over manifest.json + weights.bin + weights_q8.bin bytes
/// (absent weight files contribute nothing).  This is both the snapshot
/// staleness key and the registry's no-op-reload detector.
pub fn artifact_content_hash(root: &Path) -> Result<u64> {
    let mpath = root.join("manifest.json");
    let mbytes = std::fs::read(&mpath)
        .with_context(|| format!("reading {}", mpath.display()))?;
    let wbytes = std::fs::read(root.join("weights.bin")).unwrap_or_default();
    let qbytes = std::fs::read(root.join("weights_q8.bin")).unwrap_or_default();
    Ok(bytes_key_parts(&[&mbytes, &wbytes, &qbytes]))
}

impl ReplicaSnapshot {
    /// Snapshot file path for an artifact directory.
    pub fn path_for(root: &Path) -> PathBuf {
        root.join(SNAPSHOT_FILE)
    }

    /// Does the warm-plan cover `kind` (i.e. may a build from this
    /// snapshot skip the warm-up inference)?
    pub fn warm_covers(&self, kind: EngineKind) -> bool {
        self.warm_plan.contains(&kind)
    }

    /// Capture a snapshot from the live artifact directory of an
    /// already-validated `manifest`.  Reads manifest.json and the weight
    /// bins once, decodes every parameter into engine-ready buffers, and
    /// stamps the content hash from the exact bytes read.
    pub fn capture(manifest: &Manifest, warm_plan: &[EngineKind]) -> Result<ReplicaSnapshot> {
        let root = &manifest.root;
        let mpath = root.join("manifest.json");
        let mbytes = std::fs::read(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let wbytes = std::fs::read(root.join("weights.bin")).unwrap_or_default();
        let qbytes = std::fs::read(root.join("weights_q8.bin")).unwrap_or_default();
        let content_hash = bytes_key_parts(&[&mbytes, &wbytes, &qbytes]);

        let total: usize = manifest.params.iter().map(|p| p.nelems).sum();
        if !manifest.params.is_empty() && wbytes.len() != total * 4 {
            bail!(
                "weights.bin is {} bytes, manifest wants {}",
                wbytes.len(),
                total * 4
            );
        }
        let mut f32_bufs = BTreeMap::new();
        for p in &manifest.params {
            let lo = p.offset * 4;
            let hi = lo + p.nelems * 4;
            if hi > wbytes.len() {
                bail!("param {} spans past weights.bin", p.name);
            }
            let vals: Vec<f32> = wbytes[lo..hi]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            f32_bufs.insert(p.name.clone(), vals);
        }
        let mut q8_bufs = BTreeMap::new();
        if !qbytes.is_empty() {
            for p in &manifest.params_q8 {
                let hi = p.offset + p.nelems;
                if hi > qbytes.len() {
                    bail!("q8 param {} spans past weights_q8.bin", p.name);
                }
                q8_bufs.insert(p.name.clone(), qbytes[p.offset..hi].to_vec());
            }
        }

        let manifest_text = String::from_utf8(mbytes).context("manifest.json utf8")?;
        // Re-parse the embedded text so the snapshot's manifest is
        // exactly what a loader will reconstruct (not the caller's
        // possibly-drifted copy).
        let manifest = Manifest::parse(&manifest_text, root)?;
        Ok(ReplicaSnapshot {
            content_hash,
            input_hw: manifest.input_hw,
            num_classes: manifest.num_classes,
            batch_sizes: manifest.batch_sizes.clone(),
            manifest,
            manifest_text,
            f32_bufs,
            q8_bufs,
            warm_plan: warm_plan.to_vec(),
        })
    }

    /// Load `<root>/replica.zsnap`, fully validating before trusting:
    /// magic, version, trailing checksum, embedded-manifest re-parse,
    /// sizing cross-checks, and the content hash against the *live*
    /// artifacts in `root`.  Any failure is an `Err` — callers fall back
    /// to cold build.
    pub fn load(root: &Path) -> Result<ReplicaSnapshot> {
        let path = Self::path_for(root);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let snap = Self::decode(&bytes, root)?;
        let live = artifact_content_hash(root)?;
        if live != snap.content_hash {
            bail!(
                "snapshot is stale: artifacts hash {live:#x}, snapshot captured {:#x}",
                snap.content_hash
            );
        }
        Ok(snap)
    }

    /// Atomically write `<root>/replica.zsnap` (tmp + rename).
    pub fn write(&self, root: &Path) -> Result<()> {
        let bytes = self.encode();
        let tmp = root.join(format!("{SNAPSHOT_FILE}.tmp"));
        let dst = Self::path_for(root);
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &dst)
            .with_context(|| format!("renaming into {}", dst.display()))?;
        Ok(())
    }

    /// Serialize: magic, version, content hash, payload, trailing
    /// FNV-1a-64 checksum over everything before it.  All integers LE.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4096);
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, SNAPSHOT_VERSION);
        put_u64(&mut out, self.content_hash);
        put_bytes(&mut out, self.manifest_text.as_bytes());
        put_u32(&mut out, self.f32_bufs.len() as u32);
        for (name, vals) in &self.f32_bufs {
            put_bytes(&mut out, name.as_bytes());
            put_u32(&mut out, vals.len() as u32);
            for v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        put_u32(&mut out, self.q8_bufs.len() as u32);
        for (name, buf) in &self.q8_bufs {
            put_bytes(&mut out, name.as_bytes());
            put_bytes(&mut out, buf);
        }
        put_u32(&mut out, self.input_hw as u32);
        put_u32(&mut out, self.num_classes as u32);
        put_u32(&mut out, self.batch_sizes.len() as u32);
        for &b in &self.batch_sizes {
            put_u32(&mut out, b as u32);
        }
        put_u32(&mut out, self.warm_plan.len() as u32);
        for k in &self.warm_plan {
            put_bytes(&mut out, k.as_str().as_bytes());
        }
        let sum = bytes_key_parts(&[&out]);
        put_u64(&mut out, sum);
        out
    }

    /// Parse + validate an encoded snapshot.  Every read is
    /// bounds-checked against the remaining buffer (a bit-flipped length
    /// field fails cleanly instead of allocating gigabytes), and nothing
    /// is trusted before the trailing checksum verifies.
    pub fn decode(bytes: &[u8], root: &Path) -> Result<ReplicaSnapshot> {
        if bytes.len() < MAGIC.len() + 4 + 8 + 8 {
            bail!("snapshot too short ({} bytes)", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored_sum = u64::from_le_bytes(tail.try_into().unwrap());
        let actual_sum = bytes_key_parts(&[body]);
        if stored_sum != actual_sum {
            bail!("snapshot checksum mismatch (corrupt or truncated)");
        }
        let mut cur = Cur { b: body, i: 0 };
        let magic = cur.take(MAGIC.len())?;
        if magic != MAGIC {
            bail!("not a zsnap file (bad magic)");
        }
        let version = cur.u32()?;
        if version != SNAPSHOT_VERSION {
            bail!("snapshot version {version}, runtime speaks {SNAPSHOT_VERSION}");
        }
        let content_hash = cur.u64()?;
        let manifest_text =
            String::from_utf8(cur.bytes32()?.to_vec()).context("manifest text utf8")?;
        let n_f32 = cur.u32()? as usize;
        let mut f32_bufs = BTreeMap::new();
        for _ in 0..n_f32 {
            let name = cur.str32()?;
            let nelems = cur.u32()? as usize;
            let raw = cur.take(nelems.checked_mul(4).context("f32 buf overflow")?)?;
            let vals: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            f32_bufs.insert(name, vals);
        }
        let n_q8 = cur.u32()? as usize;
        let mut q8_bufs = BTreeMap::new();
        for _ in 0..n_q8 {
            let name = cur.str32()?;
            q8_bufs.insert(name, cur.bytes32()?.to_vec());
        }
        let input_hw = cur.u32()? as usize;
        let num_classes = cur.u32()? as usize;
        let n_batch = cur.u32()? as usize;
        let mut batch_sizes = Vec::new();
        for _ in 0..n_batch {
            batch_sizes.push(cur.u32()? as usize);
        }
        let n_warm = cur.u32()? as usize;
        let mut warm_plan = Vec::new();
        for _ in 0..n_warm {
            warm_plan.push(EngineKind::parse(&cur.str32()?)?);
        }
        if cur.i != body.len() {
            bail!("snapshot has {} trailing payload bytes", body.len() - cur.i);
        }

        let manifest = Manifest::parse(&manifest_text, root)
            .context("snapshot embedded manifest")?;
        // Sizing fields must agree with the embedded manifest; a
        // disagreement means the payload was assembled inconsistently.
        if input_hw != manifest.input_hw
            || num_classes != manifest.num_classes
            || batch_sizes != manifest.batch_sizes
        {
            bail!("snapshot sizing disagrees with its embedded manifest");
        }
        Ok(ReplicaSnapshot {
            content_hash,
            manifest,
            manifest_text,
            f32_bufs,
            q8_bufs,
            input_hw,
            num_classes,
            batch_sizes,
            warm_plan,
        })
    }

    /// Resident payload size (for replica-cache style accounting/logs).
    pub fn resident_bytes(&self) -> usize {
        let f: usize = self.f32_bufs.values().map(|v| v.len() * 4).sum();
        let q: usize = self.q8_bufs.values().map(|v| v.len()).sum();
        f + q
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

/// Bounds-checked cursor over the snapshot body.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.i.checked_add(n).context("snapshot length overflow")?;
        if end > self.b.len() {
            bail!(
                "snapshot truncated: want {n} bytes at {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes32(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    fn str32(&mut self) -> Result<String> {
        String::from_utf8(self.bytes32()?.to_vec()).context("snapshot string utf8")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zuluko_snap_unit_{tag}_{}",
            std::process::id()
        ));
        crate::testkit::manifest::write_synthetic(&dir, tag, 100, 32, &[1, 2]).unwrap();
        dir
    }

    #[test]
    fn capture_write_load_roundtrip() {
        let dir = synth_dir("rt");
        let m = Manifest::load(&dir).unwrap();
        let snap = ReplicaSnapshot::capture(&m, &[EngineKind::Sim]).unwrap();
        snap.write(&dir).unwrap();
        let back = ReplicaSnapshot::load(&dir).unwrap();
        assert_eq!(back.content_hash, snap.content_hash);
        assert_eq!(back.manifest.model, "rt");
        assert_eq!(back.input_hw, 32);
        assert_eq!(back.batch_sizes, vec![1, 2]);
        assert!(back.warm_covers(EngineKind::Sim));
        assert!(!back.warm_covers(EngineKind::Quant));
        // No tmp file left behind.
        assert!(!dir.join(format!("{SNAPSHOT_FILE}.tmp")).exists());
    }

    #[test]
    fn bitflip_fails_checksum() {
        let dir = synth_dir("flip");
        let m = Manifest::load(&dir).unwrap();
        let snap = ReplicaSnapshot::capture(&m, &[]).unwrap();
        let mut bytes = snap.encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = ReplicaSnapshot::decode(&bytes, &dir).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_fails_cleanly() {
        let dir = synth_dir("trunc");
        let m = Manifest::load(&dir).unwrap();
        let bytes = ReplicaSnapshot::capture(&m, &[]).unwrap().encode();
        for keep in [0, 7, 19, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                ReplicaSnapshot::decode(&bytes[..keep], &dir).is_err(),
                "decode of {keep}-byte prefix should fail"
            );
        }
    }

    #[test]
    fn version_skew_is_rejected() {
        let dir = synth_dir("skew");
        let m = Manifest::load(&dir).unwrap();
        let mut bytes = ReplicaSnapshot::capture(&m, &[]).unwrap().encode();
        // Bump the version field (right after the magic), then re-seal
        // the checksum so only the version check can object.
        bytes[MAGIC.len()] = 99;
        let n = bytes.len();
        let sum = bytes_key_parts(&[&bytes[..n - 8]]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = ReplicaSnapshot::decode(&bytes, &dir).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn stale_content_hash_rejected_on_load() {
        let dir = synth_dir("stale");
        let m = Manifest::load(&dir).unwrap();
        ReplicaSnapshot::capture(&m, &[]).unwrap().write(&dir).unwrap();
        // Mutate the artifacts after capture: same schema, different text.
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"stale\"", "\"stale2\"")).unwrap();
        let err = ReplicaSnapshot::load(&dir).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
    }
}
