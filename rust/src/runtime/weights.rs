//! Weight store: loads weights.bin / weights_q8.bin once and serves
//! per-parameter `xla::Literal`s (and raw slices) to the engines.
//!
//! Literals are materialized eagerly at load time — the request path never
//! touches the filesystem or re-encodes a weight (the paper's engine keeps
//! weights resident the same way; 5 MB fp32 + 1.2 MB int8 ≈ the paper's
//! ~10 MB memory story).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use super::manifest::Manifest;

/// All parameters, resident as XLA literals keyed by name.
pub struct WeightStore {
    f32_lits: BTreeMap<String, xla::Literal>,
    q8_lits: BTreeMap<String, xla::Literal>,
    /// Raw fp32 copy kept for goldens/debug (cheap: one network).
    f32_raw: BTreeMap<String, Vec<f32>>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let wpath = manifest.root.join("weights.bin");
        let bytes = std::fs::read(&wpath)
            .with_context(|| format!("reading {}", wpath.display()))?;
        let total: usize = manifest.params.iter().map(|p| p.nelems).sum();
        if bytes.len() != total * 4 {
            bail!(
                "weights.bin is {} bytes, manifest wants {}",
                bytes.len(),
                total * 4
            );
        }

        let mut f32_lits = BTreeMap::new();
        let mut f32_raw = BTreeMap::new();
        for p in &manifest.params {
            let lo = p.offset * 4;
            let hi = lo + p.nelems * 4;
            let chunk = &bytes[lo..hi];
            let vals: Vec<f32> = chunk
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &p.shape,
                chunk,
            )
            .with_context(|| format!("literal for {}", p.name))?;
            f32_lits.insert(p.name.clone(), lit);
            f32_raw.insert(p.name.clone(), vals);
        }

        let mut q8_lits = BTreeMap::new();
        let qpath = manifest.root.join("weights_q8.bin");
        if qpath.exists() {
            let qbytes = std::fs::read(&qpath)
                .with_context(|| format!("reading {}", qpath.display()))?;
            for p in &manifest.params_q8 {
                let chunk = &qbytes[p.offset..p.offset + p.nelems];
                let lit = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    &p.shape,
                    chunk,
                )
                .with_context(|| format!("q8 literal for {}", p.name))?;
                q8_lits.insert(p.name.clone(), lit);
            }
        }

        Ok(WeightStore {
            f32_lits,
            q8_lits,
            f32_raw,
        })
    }

    /// Rebuild a store from pre-decoded per-parameter buffers — the
    /// replica-snapshot fast path.  No filesystem reads and no
    /// weights.bin framing re-validation happen here; the snapshot layer
    /// has already checksummed the buffers and matched them against the
    /// manifest content-hash.  Per-param lengths are still checked so a
    /// logic bug upstream fails loudly instead of serving garbage.
    pub fn from_decoded(
        manifest: &Manifest,
        f32_bufs: &BTreeMap<String, Vec<f32>>,
        q8_bufs: &BTreeMap<String, Vec<u8>>,
    ) -> Result<WeightStore> {
        let mut f32_lits = BTreeMap::new();
        let mut f32_raw = BTreeMap::new();
        for p in &manifest.params {
            let vals = f32_bufs
                .get(&p.name)
                .with_context(|| format!("snapshot missing f32 buffer for {}", p.name))?;
            if vals.len() != p.nelems {
                bail!(
                    "snapshot f32 buffer for {} has {} elems, manifest wants {}",
                    p.name,
                    vals.len(),
                    p.nelems
                );
            }
            let lit = super::literal_from_slice(&p.shape, vals)
                .with_context(|| format!("literal for {}", p.name))?;
            f32_lits.insert(p.name.clone(), lit);
            f32_raw.insert(p.name.clone(), vals.clone());
        }

        let mut q8_lits = BTreeMap::new();
        for p in &manifest.params_q8 {
            // q8 buffers are optional as a set (weights_q8.bin may be
            // absent) but must be complete if any are present.
            let Some(chunk) = q8_bufs.get(&p.name) else {
                if q8_bufs.is_empty() {
                    continue;
                }
                bail!("snapshot missing q8 buffer for {}", p.name);
            };
            if chunk.len() != p.nelems {
                bail!(
                    "snapshot q8 buffer for {} has {} bytes, manifest wants {}",
                    p.name,
                    chunk.len(),
                    p.nelems
                );
            }
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S8,
                &p.shape,
                chunk,
            )
            .with_context(|| format!("q8 literal for {}", p.name))?;
            q8_lits.insert(p.name.clone(), lit);
        }

        Ok(WeightStore {
            f32_lits,
            q8_lits,
            f32_raw,
        })
    }

    /// Literal for a parameter (fp32 table first, then q8 table).
    pub fn literal(&self, name: &str) -> Result<&xla::Literal> {
        self.f32_lits
            .get(name)
            .or_else(|| self.q8_lits.get(name))
            .with_context(|| format!("no literal for param {name}"))
    }

    pub fn raw_f32(&self, name: &str) -> Option<&[f32]> {
        self.f32_raw.get(name).map(|v| v.as_slice())
    }

    pub fn total_f32_params(&self) -> usize {
        self.f32_raw.values().map(|v| v.len()).sum()
    }
}
