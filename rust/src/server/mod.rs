//! TCP serving frontend: newline-JSON protocol over the coordinator.
//!
//! Thread-per-connection with a hard connection cap (embedded budget);
//! each connection handles requests sequentially but the coordinator
//! batches *across* connections — that cross-request coalescing is where
//! serving throughput comes from (E7).

pub mod client;
pub mod protocol;

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::{Coordinator, SubmitError};
use crate::policy::Slo;
use crate::tensor::image::Image;
use crate::tensor::{PooledTensor, TensorPool};

use protocol::{ClientMsg, ImageSpec};

const MAX_CONNECTIONS: usize = 32;

/// Running server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: std::thread::JoinHandle<()>,
}

impl Server {
    /// Bind and serve on a background accept thread.
    pub fn start(coord: Arc<Coordinator>, listen: &str) -> Result<Server> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let conns = Arc::new(AtomicUsize::new(0));

        let accept_thread = std::thread::Builder::new()
            .name("zuluko-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            if conns.load(Ordering::Relaxed) >= MAX_CONNECTIONS {
                                crate::warn!("server", "rejecting {peer}: at connection cap");
                                drop(stream);
                                continue;
                            }
                            conns.fetch_add(1, Ordering::Relaxed);
                            let coord = coord.clone();
                            let conns = conns.clone();
                            std::thread::spawn(move || {
                                // Drop guard so the slot is released even if
                                // the handler panics mid-connection.
                                struct Slot(Arc<AtomicUsize>);
                                impl Drop for Slot {
                                    fn drop(&mut self) {
                                        self.0.fetch_sub(1, Ordering::Relaxed);
                                    }
                                }
                                let _slot = Slot(conns);
                                let _ = handle_conn(stream, &coord);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            crate::error!("server", "accept: {e}");
                            break;
                        }
                    }
                }
            })
            .expect("spawn accept thread");

        crate::info!("server", "listening on {addr}");
        Ok(Server {
            addr,
            stop,
            accept_thread,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.accept_thread.join();
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&line) {
            Err(e) => {
                protocol::error_line_kind(0, "bad_request", &format!("bad request: {e}"))
            }
            Ok(ClientMsg::Ping) => "{\"ok\":true,\"pong\":true}".to_string(),
            Ok(ClientMsg::Stats) => protocol::stats_line(&coord.stats()),
            Ok(ClientMsg::Policy) => protocol::policy_line(&coord.policy_snapshot()),
            Ok(ClientMsg::Models) => {
                protocol::models_line(coord.default_model(), &coord.stats().models)
            }
            Ok(ClientMsg::Reload { model }) => match coord.reload(model.as_deref()) {
                Ok(report) => protocol::reload_line(&report),
                Err(e) => {
                    protocol::error_line_kind(0, "reload_failed", &format!("{e:#}"))
                }
            },
            Ok(ClientMsg::Infer {
                id,
                image,
                slo,
                model,
            }) => infer_reply(coord, id, model.as_deref(), &image, slo),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// One inference request end-to-end: resolve the model (structured
/// reject on unknown names — never a default fallback), consult the
/// per-model wire-key cache, decode into the model's arena, submit.
///
/// A hot reload can retire the resolved generation between resolve and
/// route (`SubmitError::Closed`); the retry re-resolves and resubmits
/// the **already-decoded pixels** (handed back by
/// [`Coordinator::submit_on_reclaim`]) to the fresh generation —
/// decode runs again only in the rare case where the reload changed
/// the model's input size, so the swap stays invisible to the client
/// without paying a second decode.
fn infer_reply(
    coord: &Coordinator,
    id: u64,
    model: Option<&str>,
    image: &ImageSpec,
    slo: Slo,
) -> String {
    const ATTEMPTS: usize = 2;
    let mut decoded: Option<PooledTensor> = None;
    for attempt in 0..ATTEMPTS {
        let lease = match coord.lease(model) {
            Ok(l) => l,
            Err(e @ SubmitError::UnknownModel(_)) => {
                return protocol::error_line_kind(id, "unknown_model", &e.to_string())
            }
            Err(e @ SubmitError::ModelUnavailable { .. }) => {
                return protocol::error_line_kind(id, "model_unavailable", &e.to_string())
            }
            Err(e) => return protocol::error_line(id, &e.to_string()),
        };
        // Wire-key fast path: a repeat of the same raw image spec is
        // answered from this model's response cache before any pixel is
        // decoded.  Per-model caches make the key collision-free across
        // models by construction.
        let wire_key = protocol::wire_key(image);
        if let Some(mut resp) = wire_key.and_then(|k| lease.cached_response(k)) {
            resp.id = id;
            return protocol::response_line(&resp);
        }
        // Reuse the pixels reclaimed from a Closed first attempt when
        // they still fit the (possibly re-sized) fresh generation.
        let hw = lease.input_hw();
        let tensor = match decoded.take().filter(|t| t.shape() == [hw, hw, 3]) {
            Some(t) => t,
            None => match load_image(image, hw, &lease.arena()) {
                Err(e) => return protocol::error_line(id, &format!("image: {e}")),
                Ok(t) => t,
            },
        };
        return match coord.submit_on_reclaim(&lease, tensor, slo, wire_key) {
            Err((SubmitError::Closed, img)) if attempt + 1 < ATTEMPTS => {
                decoded = img;
                continue;
            }
            Err((SubmitError::Overloaded, _)) => {
                protocol::error_line_kind(id, "overloaded", "overloaded")
            }
            Err((
                SubmitError::Shed {
                    predicted_ms,
                    deadline_ms,
                },
                _,
            )) => protocol::shed_line(id, predicted_ms, deadline_ms),
            Err((e, _)) => protocol::error_line(id, &e.to_string()),
            Ok(rx) => match rx.recv() {
                Ok(mut resp) => {
                    resp.id = id; // echo client id, not internal id
                    protocol::response_line(&resp)
                }
                Err(_) => protocol::error_line(id, "worker gone"),
            },
        };
    }
    protocol::error_line(id, "closed")
}

/// Decode straight into a pooled lease — steady-state decode allocates
/// no pixel buffers (the synthetic/ppm byte staging still does; pixels
/// are the hot part).  The lease comes from the *addressed model's*
/// arena at that model's input size.
fn load_image(spec: &ImageSpec, hw: usize, pool: &TensorPool) -> Result<PooledTensor> {
    let img = match spec {
        ImageSpec::Synthetic(seed) => Image::synthetic(hw, hw, *seed),
        ImageSpec::Ppm(path) => Image::load_ppm(std::path::Path::new(path))?,
    };
    let mut buf = pool.lease(hw * hw * 3);
    img.to_input_into_sized(&mut buf, hw);
    // (H, W, C): the coordinator packs batches itself.
    PooledTensor::new(&[hw, hw, 3], buf)
}
