//! TCP serving frontend: newline-JSON protocol over the coordinator.
//!
//! Two connection planes behind one [`Server`] facade:
//!
//! - **event** (default): an epoll reactor — one acceptor plus a small
//!   fixed IO thread set multiplexing thousands of non-blocking
//!   connections, with per-connection request pipelining, pooled
//!   buffers, write backpressure, and async worker completions
//!   ([`reactor`]).  Thread count is independent of connection count.
//! - **threads** (`--conn-plane threads`): the pre-reactor
//!   thread-per-connection architecture, kept as the E13 ablation
//!   baseline ([`threads`]).
//!
//! Either way the coordinator batches *across* connections — that
//! cross-request coalescing is where serving throughput comes from
//! (E7); the connection plane decides how many sockets can feed it.

pub mod client;
pub mod conn;
pub mod protocol;
pub mod reactor;
pub mod sys;
pub mod threads;

use anyhow::{Context, Result};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::{ConnPlane, ServerConfig};
use crate::coordinator::Coordinator;
use crate::tensor::image::Image;
use crate::tensor::{PooledTensor, TensorPool};

use protocol::ImageSpec;

/// Connection-plane counters shared by both planes (a subset applies
/// to each; the threads plane has no buffer pool or pause machinery).
#[derive(Default)]
pub struct ConnStats {
    /// Currently-open connections.
    pub connections: AtomicUsize,
    /// Connections accepted over the server's lifetime.
    pub accepted: AtomicU64,
    /// Connections answered `at_capacity` and closed at the cap.
    pub rejected_at_capacity: AtomicU64,
    /// Requests rejected for exceeding `max_line_bytes`.
    pub oversize_rejected: AtomicU64,
    /// Times a connection's reads were paused because its write
    /// backlog crossed the high watermark.
    pub backpressure_events: AtomicU64,
    /// Connections evicted by the idle timeout.
    pub idle_evicted: AtomicU64,
    /// Inference requests submitted and not yet answered (event plane).
    pub in_flight: AtomicUsize,
    /// Highest per-connection in-flight depth observed (pipelining).
    pub peak_conn_in_flight: AtomicUsize,
    /// Async completions delivered (event plane).
    pub completions: AtomicU64,
    /// Connections that negotiated `binary_frames` via `{"cmd":"hello"}`.
    pub frames_negotiated: AtomicU64,
    /// Binary frame payloads accepted and decoded.
    pub frames_received: AtomicU64,
    /// Frame payload bytes ingested off the wire.
    pub frame_bytes: AtomicU64,
    /// Frames rejected (`bad_frame` / `unsupported_feature`), whether
    /// or not the payload could be skipped.
    pub frames_rejected: AtomicU64,
}

impl ConnStats {
    pub fn snapshot(
        &self,
        plane: &'static str,
        wire_parser: &'static str,
        io_threads: usize,
        pool: conn::BufPoolStats,
    ) -> ConnPlaneSnapshot {
        ConnPlaneSnapshot {
            plane,
            wire_parser,
            io_threads,
            connections: self.connections.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected_at_capacity: self.rejected_at_capacity.load(Ordering::Relaxed),
            oversize_rejected: self.oversize_rejected.load(Ordering::Relaxed),
            backpressure_events: self.backpressure_events.load(Ordering::Relaxed),
            idle_evicted: self.idle_evicted.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            peak_conn_in_flight: self.peak_conn_in_flight.load(Ordering::Relaxed),
            completions: self.completions.load(Ordering::Relaxed),
            frames_negotiated: self.frames_negotiated.load(Ordering::Relaxed),
            frames_received: self.frames_received.load(Ordering::Relaxed),
            frame_bytes: self.frame_bytes.load(Ordering::Relaxed),
            frames_rejected: self.frames_rejected.load(Ordering::Relaxed),
            buffers_free: pool.free,
            buffers_outstanding: pool.outstanding,
        }
    }
}

/// Point-in-time connection-plane state for `{"cmd":"stats"}`.
#[derive(Debug, Clone, Copy)]
pub struct ConnPlaneSnapshot {
    pub plane: &'static str,
    /// Active request-line parser: `"tape"` (default) or `"tree"`.
    pub wire_parser: &'static str,
    pub io_threads: usize,
    pub connections: usize,
    pub accepted: u64,
    pub rejected_at_capacity: u64,
    pub oversize_rejected: u64,
    pub backpressure_events: u64,
    pub idle_evicted: u64,
    pub in_flight: usize,
    pub peak_conn_in_flight: usize,
    pub completions: u64,
    pub frames_negotiated: u64,
    pub frames_received: u64,
    pub frame_bytes: u64,
    pub frames_rejected: u64,
    pub buffers_free: usize,
    pub buffers_outstanding: usize,
}

enum Plane {
    Event(reactor::Reactor),
    Threads(threads::ThreadsPlane),
}

/// Running server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    plane: Plane,
}

impl Server {
    /// Bind and serve with default connection-plane settings (event
    /// plane).  Kept source-compatible for tests and examples.
    pub fn start(coord: Arc<Coordinator>, listen: &str) -> Result<Server> {
        Self::start_with(coord, listen, &ServerConfig::default())
    }

    /// Bind and serve with explicit connection-plane configuration.
    pub fn start_with(
        coord: Arc<Coordinator>,
        listen: &str,
        cfg: &ServerConfig,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let plane = match cfg.conn_plane {
            ConnPlane::Event => {
                Plane::Event(reactor::Reactor::start(coord, listener, cfg)?)
            }
            ConnPlane::Threads => {
                Plane::Threads(threads::ThreadsPlane::start(coord, listener, cfg)?)
            }
        };
        crate::info!("server", "listening on {addr} ({} plane)", cfg.conn_plane);
        Ok(Server { addr, plane })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Connection-plane counters (what `{"cmd":"stats"}` reports under
    /// `"conn"`), exposed for tests and stress drivers.
    pub fn conn_snapshot(&self) -> ConnPlaneSnapshot {
        match &self.plane {
            Plane::Event(r) => r.snapshot(),
            Plane::Threads(t) => t.snapshot(),
        }
    }

    pub fn stop(self) {
        match self.plane {
            Plane::Event(r) => r.stop(),
            Plane::Threads(t) => t.stop(),
        }
    }
}

/// Where a request's pixels come from: a parsed spec (synthetic/ppm)
/// or a binary frame payload still borrowed from the connection's read
/// buffer (the zero-copy lane).  Both planes route their decode through
/// [`load_pixels`] so the Closed-retry loop serves both lanes.
pub(crate) enum PixelSource<'a> {
    Spec(&'a ImageSpec),
    Frame(&'a protocol::FrameHeader, &'a [u8]),
}

pub(crate) fn load_pixels(
    src: &PixelSource<'_>,
    hw: usize,
    pool: &TensorPool,
) -> Result<PooledTensor> {
    match src {
        PixelSource::Spec(spec) => load_image(spec, hw, pool),
        PixelSource::Frame(header, payload) => load_frame(header, payload, hw, pool),
    }
}

/// Decode straight into a pooled lease — steady-state decode allocates
/// no pixel buffers (the synthetic/ppm byte staging still does; pixels
/// are the hot part).  The lease comes from the *addressed model's*
/// arena at that model's input size.
pub(crate) fn load_image(
    spec: &ImageSpec,
    hw: usize,
    pool: &TensorPool,
) -> Result<PooledTensor> {
    let img = match spec {
        ImageSpec::Synthetic(seed) => Image::synthetic(hw, hw, *seed),
        ImageSpec::Ppm(path) => Image::load_ppm(std::path::Path::new(path))?,
        // Frame payloads live in the connection's read buffer; the
        // planes decode them via `load_frame` at the point the bytes
        // exist.  Reaching here would be a plane bug, not a bad client.
        ImageSpec::Frame(_) => {
            anyhow::bail!("frame payload not available on the spec-only decode path")
        }
    };
    let mut buf = pool.lease(hw * hw * 3);
    img.to_input_into_sized(&mut buf, hw);
    // (H, W, C): the coordinator packs batches itself.
    PooledTensor::new(&[hw, hw, 3], buf)
}

/// Decode a validated binary frame payload straight into a pooled
/// lease — the zero-copy lane: `payload` is borrowed from the pooled
/// connection read buffer and preprocessed directly into the model
/// arena, with no intermediate pixel `Vec`.  The header must already
/// have passed [`protocol::FrameHeader::check`].
pub(crate) fn load_frame(
    header: &protocol::FrameHeader,
    payload: &[u8],
    hw: usize,
    pool: &TensorPool,
) -> Result<PooledTensor> {
    debug_assert_eq!(payload.len(), header.len, "framing delivered wrong span");
    let mut buf = pool.lease(hw * hw * 3);
    Image::frame_to_input_into(payload, header.w, header.h, &mut buf, hw);
    PooledTensor::new(&[hw, hw, 3], buf)
}
