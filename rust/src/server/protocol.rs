//! Wire protocol: newline-delimited JSON over TCP.
//!
//! Request (one line):
//! ```json
//! {"id": 7, "image": {"synthetic": 12345}}          // seeded test image
//! {"id": 8, "image": {"ppm": "/path/frame.ppm"}}    // file on the device
//! {"cmd": "stats"}                                  // live stats
//! {"cmd": "ping"}
//! ```
//!
//! Response (one line):
//! ```json
//! {"id":7,"ok":true,"top1":694,"top5":[[694,0.01],...],
//!  "queue_ms":0.1,"exec_ms":212.4,"total_ms":231.0,"batch":2}
//! {"id":8,"ok":false,"error":"overloaded"}
//! ```
//!
//! Embedded-friendly: the device never receives bulk pixel data over the
//! demo protocol (images are either on-device files or synthetic); an
//! ingestion path would replace this transport without touching the
//! coordinator.

use anyhow::{bail, Result};

use crate::coordinator::Response;
use crate::util::json::Json;

/// Parsed client message.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Infer { id: u64, image: ImageSpec },
    Stats,
    Ping,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ImageSpec {
    Synthetic(u64),
    Ppm(String),
}

pub fn parse_request(line: &str) -> Result<ClientMsg> {
    let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "stats" => Ok(ClientMsg::Stats),
            "ping" => Ok(ClientMsg::Ping),
            other => bail!("unknown cmd {other}"),
        };
    }
    let id = j
        .get("id")
        .and_then(|v| v.as_f64())
        .map(|f| f as u64)
        .unwrap_or(0);
    let img = j
        .get("image")
        .ok_or_else(|| anyhow::anyhow!("missing image"))?;
    let image = if let Some(seed) = img.get("synthetic").and_then(|v| v.as_f64()) {
        ImageSpec::Synthetic(seed as u64)
    } else if let Some(p) = img.get("ppm").and_then(|v| v.as_str()) {
        ImageSpec::Ppm(p.to_string())
    } else {
        bail!("image must have 'synthetic' or 'ppm'");
    };
    Ok(ClientMsg::Infer { id, image })
}

pub fn response_line(r: &Response) -> String {
    let mut o = Json::obj();
    o.set("id", r.id.into());
    match &r.error {
        Some(e) => {
            o.set("ok", false.into()).set("error", e.as_str().into());
        }
        None => {
            o.set("ok", true.into())
                .set("top1", r.top1.into())
                .set(
                    "top5",
                    Json::Arr(
                        r.top5
                            .iter()
                            .map(|(i, p)| {
                                Json::Arr(vec![(*i).into(), Json::Num(*p as f64)])
                            })
                            .collect(),
                    ),
                )
                .set("queue_ms", r.queue_ms.into())
                .set("exec_ms", r.exec_ms.into())
                .set("total_ms", r.total_ms.into())
                .set("batch", r.batch_size.into())
                .set("worker", r.worker.into());
        }
    }
    o.to_string()
}

pub fn error_line(id: u64, msg: &str) -> String {
    let mut o = Json::obj();
    o.set("id", id.into())
        .set("ok", false.into())
        .set("error", msg.into());
    o.to_string()
}

pub fn stats_line(s: &crate::coordinator::StatsSnapshot) -> String {
    let (mean, p50, p95, p99, max) = s.latency_summary;
    let mut lat = Json::obj();
    lat.set("mean_ms", mean.into())
        .set("p50_ms", p50.into())
        .set("p95_ms", p95.into())
        .set("p99_ms", p99.into())
        .set("max_ms", max.into());
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("completed", s.completed.into())
        .set("rejected", s.rejected.into())
        .set("images", s.images.into())
        .set("queued", s.queued.into())
        .set("mean_batch", s.mean_batch.into())
        .set("latency", lat);
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_infer_synthetic() {
        let m = parse_request(r#"{"id": 7, "image": {"synthetic": 42}}"#).unwrap();
        assert_eq!(
            m,
            ClientMsg::Infer {
                id: 7,
                image: ImageSpec::Synthetic(42)
            }
        );
    }

    #[test]
    fn parse_infer_ppm() {
        let m = parse_request(r#"{"id":1,"image":{"ppm":"/tmp/x.ppm"}}"#).unwrap();
        assert!(matches!(
            m,
            ClientMsg::Infer { image: ImageSpec::Ppm(_), .. }
        ));
    }

    #[test]
    fn parse_cmds() {
        assert_eq!(parse_request(r#"{"cmd":"stats"}"#).unwrap(), ClientMsg::Stats);
        assert_eq!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), ClientMsg::Ping);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id":1}"#).is_err());
        assert!(parse_request(r#"{"id":1,"image":{}}"#).is_err());
        assert!(parse_request(r#"{"cmd":"reboot"}"#).is_err());
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = Response {
            id: 3,
            top1: 694,
            top5: vec![(694, 0.5), (1, 0.25)],
            queue_ms: 0.5,
            exec_ms: 100.0,
            total_ms: 101.0,
            batch_size: 2,
            worker: 0,
            error: None,
        };
        let line = response_line(&r);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.usize_of("top1").unwrap(), 694);
        assert_eq!(j.usize_of("batch").unwrap(), 2);
        let err = error_line(9, "overloaded");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }
}
