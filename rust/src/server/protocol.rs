//! Wire protocol: newline-delimited JSON over TCP, with an optional
//! negotiated binary pixel-frame lane.
//!
//! The complete request/reply reference — every `cmd`, every reply
//! `kind`, and the frame wire format — lives in README.md ("Wire
//! protocol") and DESIGN.md §5; this module is the single
//! implementation of that grammar for both wire parsers.
//!
//! Invariants the planes lean on:
//!
//! * `id` is mandatory on infer requests and must be a non-negative
//!   integer: replies are matched to requests by id, so a
//!   silently-defaulted id could cross-wire routing on the client.
//! * `model` is optional: absent means the default model; an unknown
//!   name is a structured `"kind":"unknown_model"` reject — never a
//!   silent fallback.
//! * Every reject, on every path and both planes, is one JSON line of
//!   the same shape: `{"id":…,"ok":false,"kind":…,"msg":…}` with
//!   `kind` drawn from the closed [`ERROR_KINDS`] set.  The deprecated
//!   `"error"` alias of `msg` (pre-`kind` clients) is gone from the
//!   default wire; `--compat-error-alias` re-enables it via
//!   [`ReplyFmt`] for one more release.
//! * Binary frames (`"image":{"frame":{…}}` + raw payload) are only
//!   legal after a `{"cmd":"hello"}` negotiation on that connection;
//!   connections that never negotiate are byte-for-byte unaffected.

use anyhow::{bail, Result};

use crate::config::WireParser;
use crate::coordinator::Response;
use crate::policy::{PolicySnapshot, Priority, Slo};
use crate::util::json::Json;
use crate::util::wire::{self, WireDoc, WireTape};

/// Parsed client message.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Infer {
        id: u64,
        image: ImageSpec,
        slo: Slo,
        /// Registry model to serve this request (None = default model).
        model: Option<String>,
    },
    Stats,
    /// Unified observability snapshot: stats + per-stage histograms +
    /// trace-plane counters, one line (DESIGN.md §10).
    Metrics,
    /// Last `n` retained request timelines plus the anomaly slow log.
    Trace { n: usize },
    Policy,
    /// Registry listing: names, generations, load state.
    Models,
    /// Hot reload a model's artifacts (None = default model).
    Reload { model: Option<String> },
    Ping,
    /// Protocol handshake: advertise capabilities and negotiate
    /// per-connection features.  `binary_frames` is the client's
    /// opt-in; unknown requested features are ignored (the reply's
    /// `negotiated` object tells the client what it actually got).
    Hello { binary_frames: bool },
}

#[derive(Debug, Clone, PartialEq)]
pub enum ImageSpec {
    Synthetic(u64),
    Ppm(String),
    /// Binary pixel frame: the header parsed off the request line; the
    /// pixel payload follows as exactly `len` raw bytes on the wire
    /// and is consumed by the connection plane, never by the parser.
    Frame(FrameHeader),
}

/// Header of a binary pixel frame, from
/// `"image":{"frame":{"len":N,"h":N,"w":N,"c":N,"dtype":"u8"}}`.
///
/// The parser only enforces JSON structure (integer dims, string
/// dtype); semantic validation — shape/len consistency, supported
/// dtype, the `--max-frame-bytes` bound — is [`FrameHeader::check`],
/// run by the plane so it can answer `bad_frame` and still resync past
/// the payload when [`FrameHeader::resyncable`] holds.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameHeader {
    /// Payload byte count that follows the request line on the wire.
    pub len: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Raw dtype tag from the wire, kept verbatim so a reject can echo
    /// it.  `"u8"` (interleaved RGB, row-major HWC) is the only
    /// supported value; it is also the default when omitted.
    pub dtype: String,
}

impl FrameHeader {
    pub const DTYPE_U8: &'static str = "u8";

    /// Can the connection consume exactly `len` payload bytes and keep
    /// serving?  True when `len` is in `(0, max_frame_bytes]` — even
    /// an otherwise-invalid header is then a recoverable `bad_frame`,
    /// because the framing layer knows how much wire to skip.
    pub fn resyncable(&self, max_frame_bytes: usize) -> bool {
        self.len > 0 && self.len <= max_frame_bytes
    }

    /// Full semantic validation; the `Err` text becomes the
    /// `bad_frame` reject's `msg`.
    pub fn check(&self, max_frame_bytes: usize) -> Result<(), String> {
        if self.len == 0 || self.len > max_frame_bytes {
            return Err(format!(
                "frame len {} outside (0, {max_frame_bytes}] (--max-frame-bytes)",
                self.len
            ));
        }
        if self.dtype != Self::DTYPE_U8 {
            return Err(format!(
                "unsupported frame dtype {:?} (supported: \"u8\")",
                self.dtype
            ));
        }
        if self.h == 0 || self.w == 0 {
            return Err(format!("frame h/w must be >= 1, got {}x{}", self.h, self.w));
        }
        if self.c != 3 {
            return Err(format!("frame c must be 3 (RGB), got {}", self.c));
        }
        match self.h.checked_mul(self.w).and_then(|p| p.checked_mul(self.c)) {
            Some(n) if n == self.len => Ok(()),
            _ => Err(format!(
                "frame len {} != h*w*c = {}*{}*{}",
                self.len, self.h, self.w, self.c
            )),
        }
    }
}

/// Pre-decode cache key: a stable hash of the raw image spec, computed
/// *before* any pixel work so a repeated request can be answered from
/// the response cache without decoding at all.  Only self-describing
/// specs are keyed — a synthetic seed fully determines the pixels, but
/// a ppm path's file can change on disk between requests, so ppm
/// requests fall through to the post-decode content-hash path.
///
/// Cost: a wire-keyed frame occupies *two* LRU slots (content key +
/// wire alias), so a stream of distinct wire-keyed frames holds about
/// `cache_capacity / 2` residents.  Size `--cache-capacity` for ~2
/// entries per distinct frame when wire-keyed traffic dominates.
pub fn wire_key(spec: &ImageSpec) -> Option<u64> {
    match spec {
        ImageSpec::Synthetic(seed) => {
            let mut buf = [0u8; 20];
            // Key bytes are `s` (domain tag vs. future spec kinds) plus
            // the seed's ASCII decimal digits — the same bytes a
            // canonical wire span carries, so the tape path can hash a
            // raw `"synthetic"` value span without re-encoding the seed
            // (see `wire_key_for_span`).
            Some(crate::policy::bytes_key_parts(&[b"s", fmt_u64(*seed, &mut buf)]))
        }
        // Neither a ppm path nor a frame header determines the pixels
        // (file contents / out-of-band payload), so both fall through
        // to the post-decode content-hash path.
        ImageSpec::Ppm(_) | ImageSpec::Frame(_) => None,
    }
}

/// Format `v` as ASCII decimal into `buf`, returning the digit slice.
/// `u64::MAX` needs 20 digits, so the fixed buffer always fits; the
/// loop is bounded by the buffer, never by the input.
fn fmt_u64(mut v: u64, buf: &mut [u8; 20]) -> &[u8] {
    let mut i = buf.len();
    loop {
        i = i.saturating_sub(1);
        if let Some(b) = buf.get_mut(i) {
            *b = b'0' + (v % 10) as u8;
        }
        v /= 10;
        if v == 0 || i == 0 {
            break;
        }
    }
    buf.get(i..).unwrap_or(&[])
}

/// Wire key straight off a tape span, allocation- and copy-free in the
/// common case.  A span that already *is* the seed's canonical decimal
/// spelling — all ASCII digits, no leading zero, and short enough
/// (<= 15 digits < 2^53) that the f64 round-trip is exact — hashes in
/// place.  Any other spelling of the same seed (`4.2e1`, `042`, a 16+
/// digit literal) is formatted canonically first, so every spelling
/// maps to the one key [`wire_key`] computes from the parsed spec.
fn wire_key_for_span(seed: u64, span: &[u8]) -> u64 {
    let canonical = !span.is_empty()
        && span.len() <= 15
        && span.iter().all(|b| b.is_ascii_digit())
        && (span.len() == 1 || span.first() != Some(&b'0'));
    if canonical {
        return crate::policy::bytes_key_parts(&[b"s", span]);
    }
    let mut buf = [0u8; 20];
    crate::policy::bytes_key_parts(&[b"s", fmt_u64(seed, &mut buf)])
}

/// Parse an optional `"model"` field: absent -> None (default model);
/// present but not a non-empty string -> parse error (a malformed model
/// must never silently become "the default model").
fn parse_model(j: &Json) -> Result<Option<String>> {
    match j.get("model") {
        None => Ok(None),
        Some(v) => match v.as_str() {
            Some(s) if !s.is_empty() => Ok(Some(s.to_string())),
            _ => bail!("'model' must be a non-empty string, got {v:?}"),
        },
    }
}

pub fn parse_request(line: &str) -> Result<ClientMsg> {
    let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "stats" => Ok(ClientMsg::Stats),
            "metrics" => Ok(ClientMsg::Metrics),
            "trace" => {
                let n = match j.get("n") {
                    None => 32,
                    Some(v) => match v.as_usize() {
                        // Clamp: the rings are bounded anyway; the cap
                        // keeps a typo from building a huge reply line.
                        Some(n) if n >= 1 => n.min(4096),
                        _ => bail!("'n' must be a positive integer, got {v:?}"),
                    },
                };
                Ok(ClientMsg::Trace { n })
            }
            "policy" => Ok(ClientMsg::Policy),
            "models" => Ok(ClientMsg::Models),
            "reload" => Ok(ClientMsg::Reload {
                model: parse_model(&j)?,
            }),
            "ping" => Ok(ClientMsg::Ping),
            "hello" => {
                let binary_frames =
                    match j.get("features").and_then(|f| f.get("binary_frames")) {
                        None => false,
                        Some(v) => match v.as_bool() {
                            Some(b) => b,
                            None => {
                                bail!("feature 'binary_frames' must be a boolean")
                            }
                        },
                    };
                Ok(ClientMsg::Hello { binary_frames })
            }
            other => bail!("unknown cmd {other}"),
        };
    }
    // id is mandatory: replies are matched by id, so defaulting it could
    // cross-wire reply routing.
    let id = match j.get("id") {
        None => bail!("missing 'id' (a non-negative integer)"),
        Some(v) => match v.as_usize() {
            Some(n) => n as u64,
            None => bail!("'id' must be a non-negative integer, got {v:?}"),
        },
    };
    let img = j
        .get("image")
        .ok_or_else(|| anyhow::anyhow!("missing image"))?;
    let image = if let Some(seed) = img.get("synthetic").and_then(|v| v.as_f64()) {
        ImageSpec::Synthetic(seed as u64)
    } else if let Some(p) = img.get("ppm").and_then(|v| v.as_str()) {
        ImageSpec::Ppm(p.to_string())
    } else if let Some(fr) = img.get("frame") {
        let dim = |key: &str| -> Result<usize> {
            match fr.get(key).and_then(|v| v.as_usize()) {
                Some(n) => Ok(n),
                None => bail!("frame '{key}' must be a non-negative integer"),
            }
        };
        let dtype = match fr.get("dtype") {
            None => FrameHeader::DTYPE_U8.to_string(),
            Some(v) => match v.as_str() {
                Some(s) => s.to_string(),
                None => bail!("frame 'dtype' must be a string"),
            },
        };
        ImageSpec::Frame(FrameHeader {
            len: dim("len")?,
            h: dim("h")?,
            w: dim("w")?,
            c: dim("c")?,
            dtype,
        })
    } else {
        bail!("image must have 'synthetic', 'ppm', or 'frame'");
    };
    let mut slo = Slo::default();
    if let Some(v) = j.get("deadline_ms") {
        match v.as_f64() {
            // Upper bound keeps Duration::from_secs_f64 from panicking on
            // absurd values (1e9 ms ≈ 11.5 days is already "no deadline").
            Some(ms) if ms > 0.0 && ms <= 1e9 => {
                slo = Slo::with_deadline_ms(ms);
            }
            _ => bail!("'deadline_ms' must be in (0, 1e9] ms, got {v:?}"),
        }
    }
    if let Some(v) = j.get("priority") {
        match v.as_str() {
            Some(s) => slo.priority = Priority::parse(s)?,
            None => bail!("'priority' must be a string (hi|normal|lo)"),
        }
    }
    let model = parse_model(&j)?;
    Ok(ClientMsg::Infer {
        id,
        image,
        slo,
        model,
    })
}

/// A tape-path reject.  Cold path: re-run the line through the tree
/// parser and return *its* error, so diagnostics stay byte-identical
/// across `--wire-parser` modes (clients and tests never see which
/// parser rejected them).  If the parsers ever disagree — the tree
/// accepts what the tape rejected — the request is still rejected,
/// with the tape's own message; the differential corpus test
/// (rust/tests/wire_props.rs) is what catches such drift.
fn tape_reject(line: &[u8], fallback: &str) -> anyhow::Error {
    match parse_request(&String::from_utf8_lossy(line)) {
        Err(e) => e,
        Ok(_) => anyhow::anyhow!("{fallback}"),
    }
}

/// Mirror of [`parse_model`] over a tape: absent -> None; present but
/// not a non-empty string -> reject.
fn tape_model(line: &[u8], doc: &WireDoc) -> Result<Option<String>> {
    match doc.get("model") {
        None => Ok(None),
        Some(f) => match doc.str_value(f) {
            Some(s) if !s.is_empty() => Ok(Some(s.into_owned())),
            _ => Err(tape_reject(line, "'model' must be a non-empty string")),
        },
    }
}

/// Tape-path parse: scan the raw line in place (no value tree, no
/// per-key allocations) and extract only the fields the hot path needs.
/// Returns the message plus the pre-decode wire key for self-describing
/// image specs, computed straight off the raw value span.
///
/// Semantics mirror [`parse_request`] branch for branch — duplicate
/// keys are last-wins, a non-string `"cmd"` falls through to the infer
/// path, numbers follow the same lax-prefix + `f64` grammar — and the
/// differential test in rust/tests/wire_props.rs holds the two parsers
/// to byte-identical accept/reject behavior.
pub fn parse_tape_keyed(
    line: &[u8],
    tape: &mut WireTape,
) -> Result<(ClientMsg, Option<u64>)> {
    let trimmed = wire::trim_ws(line);
    let doc = match wire::scan(trimmed, tape) {
        Ok(d) => d,
        Err(e) => return Err(tape_reject(line, &e.to_string())),
    };
    if let Some(cmd) = doc.get("cmd").and_then(|f| doc.str_value(f)) {
        return match &*cmd {
            "stats" => Ok((ClientMsg::Stats, None)),
            "metrics" => Ok((ClientMsg::Metrics, None)),
            "trace" => {
                let n = match doc.get("n") {
                    None => 32,
                    Some(f) => match doc.usize_value(f) {
                        Some(n) if n >= 1 => n.min(4096),
                        _ => {
                            return Err(tape_reject(
                                line,
                                "'n' must be a positive integer",
                            ))
                        }
                    },
                };
                Ok((ClientMsg::Trace { n }, None))
            }
            "policy" => Ok((ClientMsg::Policy, None)),
            "models" => Ok((ClientMsg::Models, None)),
            "reload" => Ok((
                ClientMsg::Reload {
                    model: tape_model(line, &doc)?,
                },
                None,
            )),
            "ping" => Ok((ClientMsg::Ping, None)),
            "hello" => {
                let binary_frames = match doc
                    .get("features")
                    .and_then(|f| doc.child(f, "binary_frames"))
                {
                    None => false,
                    Some(f) => match doc.bool_value(f) {
                        Some(b) => b,
                        None => {
                            return Err(tape_reject(
                                line,
                                "feature 'binary_frames' must be a boolean",
                            ))
                        }
                    },
                };
                Ok((ClientMsg::Hello { binary_frames }, None))
            }
            _ => Err(tape_reject(line, "unknown cmd")),
        };
    }
    let id = match doc.get("id") {
        None => {
            return Err(tape_reject(line, "missing 'id' (a non-negative integer)"))
        }
        Some(f) => match doc.usize_value(f) {
            Some(n) => n as u64,
            None => {
                return Err(tape_reject(line, "'id' must be a non-negative integer"))
            }
        },
    };
    let img = match doc.get("image") {
        Some(f) => f,
        None => return Err(tape_reject(line, "missing image")),
    };
    let (image, key) = if let Some((f, v)) = doc
        .child(img, "synthetic")
        .and_then(|f| doc.f64_value(f).map(|v| (f, v)))
    {
        let seed = v as u64;
        (
            ImageSpec::Synthetic(seed),
            Some(wire_key_for_span(seed, doc.raw(f))),
        )
    } else if let Some(p) = doc.child(img, "ppm").and_then(|f| doc.str_value(f)) {
        (ImageSpec::Ppm(p.into_owned()), None)
    } else if let Some(fr) = doc.child(img, "frame") {
        let dim = |key: &str| -> Result<usize> {
            match doc.child(fr, key).and_then(|f| doc.usize_value(f)) {
                Some(n) => Ok(n),
                None => Err(tape_reject(
                    line,
                    &format!("frame '{key}' must be a non-negative integer"),
                )),
            }
        };
        let (len, h, w, c) = (dim("len")?, dim("h")?, dim("w")?, dim("c")?);
        let dtype = match doc.child(fr, "dtype") {
            None => std::borrow::Cow::Borrowed(FrameHeader::DTYPE_U8),
            Some(f) => match doc.str_value(f) {
                Some(s) => s,
                None => {
                    return Err(tape_reject(line, "frame 'dtype' must be a string"))
                }
            },
        };
        (
            ImageSpec::Frame(FrameHeader {
                len,
                h,
                w,
                c,
                dtype: dtype.into_owned(),
            }),
            None,
        )
    } else {
        return Err(tape_reject(
            line,
            "image must have 'synthetic', 'ppm', or 'frame'",
        ));
    };
    let mut slo = Slo::default();
    if let Some(f) = doc.get("deadline_ms") {
        match doc.f64_value(f) {
            Some(ms) if ms > 0.0 && ms <= 1e9 => slo = Slo::with_deadline_ms(ms),
            _ => {
                return Err(tape_reject(line, "'deadline_ms' must be in (0, 1e9] ms"))
            }
        }
    }
    if let Some(f) = doc.get("priority") {
        match doc.str_value(f).map(|s| Priority::parse(&s)) {
            Some(Ok(p)) => slo.priority = p,
            Some(Err(_)) | None => {
                return Err(tape_reject(
                    line,
                    "'priority' must be a string (hi|normal|lo)",
                ))
            }
        }
    }
    let model = tape_model(line, &doc)?;
    Ok((
        ClientMsg::Infer {
            id,
            image,
            slo,
            model,
        },
        key,
    ))
}

impl ClientMsg {
    /// Tape-path entry point when the caller doesn't need the wire key.
    pub fn parse_tape(line: &[u8], tape: &mut WireTape) -> Result<ClientMsg> {
        Ok(parse_tape_keyed(line, tape)?.0)
    }
}

/// Parse one raw request line with the configured parser, returning the
/// message plus its pre-decode wire key (Infer over a self-describing
/// spec only).  Tape is the hot path; the tree parser is retained as
/// the E15 ablation baseline (`--wire-parser tree`) and produces
/// identical messages, keys, and error lines.
pub fn parse_line(
    parser: WireParser,
    line: &[u8],
    tape: &mut WireTape,
) -> Result<(ClientMsg, Option<u64>)> {
    match parser {
        WireParser::Tape => parse_tape_keyed(line, tape),
        WireParser::Tree => {
            let msg = parse_request(&String::from_utf8_lossy(line))?;
            let key = match &msg {
                ClientMsg::Infer { image, .. } => wire_key(image),
                _ => None,
            };
            Ok((msg, key))
        }
    }
}

/// Wire protocol version advertised by `{"cmd":"hello"}`.  Version 1
/// is the first to carry the handshake itself and the binary frame
/// lane; pre-hello clients are implicitly version 0 (JSON lines only).
pub const PROTOCOL_VERSION: u64 = 1;

/// The closed set of reply `kind` strings — every `"ok":false` line,
/// on every path and both planes, carries exactly one of these (the
/// conformance test in rust/tests/conn_plane.rs holds the planes to
/// it; README.md documents what each means).
pub const ERROR_KINDS: &[&str] = &[
    "bad_request",
    "bad_frame",
    "unsupported_feature",
    "at_capacity",
    "overloaded",
    "shed",
    "unknown_model",
    "model_unavailable",
    "reload_failed",
    "error",
];

/// `{"cmd":"hello"}` reply: the protocol version, the server's feature
/// list (binary frame support, the active wire parser, the serving
/// plane), and the features this connection actually negotiated.
pub fn hello_line(plane: &str, wire_parser: &str, binary_frames: bool) -> String {
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("protocol_version", PROTOCOL_VERSION.into())
        .set(
            "features",
            Json::Arr(vec![
                "binary_frames".into(),
                format!("wire_parser:{wire_parser}").into(),
                format!("plane:{plane}").into(),
            ]),
        );
    let mut neg = Json::obj();
    neg.set("binary_frames", binary_frames.into());
    o.set("negotiated", neg);
    o.to_string()
}

/// Per-plane reply formatting knobs, threaded from `ServerConfig` to
/// every site that emits an `"ok":false` line.
///
/// `error_alias` re-emits the deprecated `"error"` duplicate of `msg`
/// for pre-`kind` clients (`--compat-error-alias`).  The default wire
/// no longer carries it — the conformance test in
/// rust/tests/conn_plane.rs asserts its absence on both planes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplyFmt {
    pub error_alias: bool,
}

impl ReplyFmt {
    pub fn new(error_alias: bool) -> Self {
        Self { error_alias }
    }

    /// Append the deprecated alias when this connection's plane was
    /// started with `--compat-error-alias`.
    fn alias(&self, o: &mut Json, msg: &str) {
        if self.error_alias {
            o.set("error", msg.into());
        }
    }

    pub fn response_line(&self, r: &Response) -> String {
        let mut o = Json::obj();
        o.set("id", r.id.into());
        match &r.error {
            Some(e) => {
                o.set("ok", false.into())
                    .set("kind", r.kind.into())
                    .set("msg", e.as_str().into());
                self.alias(&mut o, e);
            }
            None => {
                o.set("ok", true.into())
                    .set("top1", r.top1.into())
                    .set(
                        "top5",
                        Json::Arr(
                            r.top5
                                .iter()
                                .map(|(i, p)| {
                                    Json::Arr(vec![(*i).into(), Json::Num(*p as f64)])
                                })
                                .collect(),
                        ),
                    )
                    .set("queue_ms", r.queue_ms.into())
                    .set("exec_ms", r.exec_ms.into())
                    .set("total_ms", r.total_ms.into())
                    .set("batch", r.batch_size.into())
                    .set("worker", r.worker.into())
                    .set("engine", r.engine.into())
                    .set("model", (&*r.model).into())
                    .set("cached", r.cached.into());
            }
        }
        o.to_string()
    }

    pub fn error_line(&self, id: u64, msg: &str) -> String {
        self.error_line_kind(id, "error", msg)
    }

    /// Structured error: `kind` is machine-matchable (one of
    /// [`ERROR_KINDS`]), `msg` is the human text.
    pub fn error_line_kind(&self, id: u64, kind: &str, msg: &str) -> String {
        debug_assert!(ERROR_KINDS.contains(&kind), "unlisted error kind {kind:?}");
        let mut o = Json::obj();
        o.set("id", id.into())
            .set("ok", false.into())
            .set("kind", kind.into())
            .set("msg", msg.into());
        self.alias(&mut o, msg);
        o.to_string()
    }

    /// Structured SLO shed: no engine variant was predicted to meet the
    /// request's deadline.  The human text is SubmitError::Shed's
    /// Display, so wire and library error messages cannot drift apart.
    pub fn shed_line(&self, id: u64, predicted_ms: f64, deadline_ms: f64) -> String {
        let msg = crate::coordinator::SubmitError::Shed {
            predicted_ms,
            deadline_ms,
        }
        .to_string();
        let mut o = Json::obj();
        o.set("id", id.into())
            .set("ok", false.into())
            .set("kind", "shed".into())
            .set("msg", msg.as_str().into());
        self.alias(&mut o, &msg);
        o.set("predicted_ms", predicted_ms.into())
            .set("deadline_ms", deadline_ms.into());
        o.to_string()
    }
}

/// Alias-free [`ReplyFmt::response_line`] for callers without a plane
/// config (benches, library users).
pub fn response_line(r: &Response) -> String {
    ReplyFmt::default().response_line(r)
}

pub fn error_line(id: u64, msg: &str) -> String {
    ReplyFmt::default().error_line(id, msg)
}

pub fn error_line_kind(id: u64, kind: &str, msg: &str) -> String {
    ReplyFmt::default().error_line_kind(id, kind, msg)
}

pub fn shed_line(id: u64, predicted_ms: f64, deadline_ms: f64) -> String {
    ReplyFmt::default().shed_line(id, predicted_ms, deadline_ms)
}

pub fn stats_line(s: &crate::coordinator::StatsSnapshot) -> String {
    stats_obj(s).to_string()
}

/// Stats reply with the connection-plane section the serving planes
/// attach: current connections, in-flight pipeline depth, buffer-pool
/// occupancy, and backpressure/eviction counters.
pub fn stats_line_with(
    s: &crate::coordinator::StatsSnapshot,
    conn: &super::ConnPlaneSnapshot,
) -> String {
    stats_obj_with(s, conn).to_string()
}

fn stats_obj_with(
    s: &crate::coordinator::StatsSnapshot,
    conn: &super::ConnPlaneSnapshot,
) -> Json {
    let mut o = stats_obj(s);
    let mut c = Json::obj();
    c.set("plane", conn.plane.into())
        .set("wire_parser", conn.wire_parser.into())
        .set("io_threads", conn.io_threads.into())
        .set("connections", conn.connections.into())
        .set("accepted", conn.accepted.into())
        .set("rejected_at_capacity", conn.rejected_at_capacity.into())
        .set("oversize_rejected", conn.oversize_rejected.into())
        .set("backpressure_events", conn.backpressure_events.into())
        .set("idle_evicted", conn.idle_evicted.into())
        .set("in_flight", conn.in_flight.into())
        .set("peak_conn_in_flight", conn.peak_conn_in_flight.into())
        .set("completions", conn.completions.into());
    let mut frames = Json::obj();
    frames
        .set("negotiated", conn.frames_negotiated.into())
        .set("received", conn.frames_received.into())
        .set("bytes", conn.frame_bytes.into())
        .set("rejected", conn.frames_rejected.into());
    c.set("frames", frames);
    let mut bufs = Json::obj();
    bufs.set("free", conn.buffers_free.into())
        .set("outstanding", conn.buffers_outstanding.into());
    c.set("buffers", bufs);
    o.set("conn", c);
    o
}

/// `"proc"` stats section: point-in-time process health from /proc
/// (None on non-Linux hosts — the section is simply omitted).
fn proc_obj() -> Option<Json> {
    let p = crate::metrics::sysmon::proc_snapshot().ok()?;
    let mut o = Json::obj();
    o.set("rss_mb", p.rss_mb.into())
        .set("cpu_s", p.cpu_s.into())
        .set("uptime_s", p.uptime_s.into())
        .set("open_fds", p.open_fds.into());
    Some(o)
}

fn stage_rows_arr(rows: &[crate::obs::StageRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let (mean, p50, p95, p99, max) = r.summary;
                let mut o = Json::obj();
                o.set("stage", r.stage.into())
                    .set("count", r.count.into())
                    .set("mean_ms", mean.into())
                    .set("p50_ms", p50.into())
                    .set("p95_ms", p95.into())
                    .set("p99_ms", p99.into())
                    .set("max_ms", max.into());
                o
            })
            .collect(),
    )
}

/// One retained timeline: marks as ms offsets from the first stamped
/// stage (unset stages omitted), plus classification flags.
fn span_obj(s: &crate::obs::Span) -> Json {
    let t0 = s.first_ns();
    let mut marks = Json::obj();
    for (i, name) in crate::obs::STAGE_NAMES.iter().enumerate() {
        if s.marks[i] != 0 {
            marks.set(name, ((s.marks[i] - t0) as f64 / 1e6).into());
        }
    }
    let mut o = Json::obj();
    o.set("id", s.id.into())
        .set("total_ms", s.total_ms().into())
        .set("marks", marks)
        .set(
            "flags",
            Json::Arr(
                crate::obs::flag_names(s.flags)
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        );
    if s.deadline_ns != 0 {
        o.set("deadline_ms", (s.deadline_ns as f64 / 1e6).into());
    }
    o
}

/// `{"cmd":"metrics"}` reply: one line merging every subsystem's view —
/// the full stats object (scheduler, queues, pool, models), the
/// connection plane, process health, per-stage latency histograms
/// (merged and per-model), and the trace-plane counters.
pub fn metrics_line(
    m: &crate::coordinator::MetricsSnapshot,
    conn: &super::ConnPlaneSnapshot,
) -> String {
    let mut o = stats_obj_with(&m.stats, conn);
    o.set("stages", stage_rows_arr(&m.stages));
    o.set(
        "model_stages",
        Json::Arr(
            m.model_stages
                .iter()
                .map(|ms| {
                    let mut row = Json::obj();
                    row.set("model", ms.model.as_str().into())
                        .set("stages", stage_rows_arr(&ms.stages));
                    row
                })
                .collect(),
        ),
    );
    let c = &m.obs;
    let mut t = Json::obj();
    t.set("begun", c.begun.into())
        .set("completed", c.completed.into())
        .set("recorded", c.recorded.into())
        .set("sampled_out", c.sampled_out.into())
        .set("anomalies", c.anomalies.into())
        .set("sample_period", c.sample_period.into())
        .set("rings", c.rings.into())
        .set("ring_capacity", c.ring_capacity.into())
        .set("slow_capacity", c.slow_capacity.into())
        .set("p999_est_ms", c.p999_est_ms.into())
        .set("flush_count", c.flush_count.into())
        .set("flush_mean_ms", c.flush_mean_ms.into())
        .set("flush_max_ms", c.flush_max_ms.into());
    o.set("trace", t);
    o.to_string()
}

/// `{"cmd":"trace"}` reply: last-`n` retained timelines (newest last)
/// plus the anomaly slow log.
pub fn trace_line(traces: &[crate::obs::Span], slow: &[crate::obs::Span]) -> String {
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("traces", Json::Arr(traces.iter().map(span_obj).collect()))
        .set("slow", Json::Arr(slow.iter().map(span_obj).collect()));
    o.to_string()
}

fn stats_obj(s: &crate::coordinator::StatsSnapshot) -> Json {
    let (mean, p50, p95, p99, max) = s.latency_summary;
    let mut lat = Json::obj();
    lat.set("mean_ms", mean.into())
        .set("p50_ms", p50.into())
        .set("p95_ms", p95.into())
        .set("p99_ms", p99.into())
        .set("max_ms", max.into());
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("completed", s.completed.into())
        .set("rejected", s.rejected.into())
        .set("images", s.images.into())
        .set("queued", s.queued.into())
        .set("mean_batch", s.mean_batch.into())
        .set("cache_hits", s.cache_hits.into())
        .set("cache_misses", s.cache_misses.into())
        .set("shed_predicted", s.shed_predicted.into())
        .set("shed_expired", s.shed_expired.into())
        .set("latency", lat);
    let mut pool = Json::obj();
    pool.set("hits", s.pool.hits.into())
        .set("misses", s.pool.misses.into())
        .set("returned", s.pool.returned.into())
        .set("dropped", s.pool.dropped.into())
        .set("buffers", s.pool.buffers.into());
    o.set("pool", pool);
    o.set(
        "models",
        Json::Arr(s.models.iter().map(model_stats_obj).collect()),
    );
    // Scheduler health (DESIGN.md §4): per-worker occupancy and
    // per-(model, engine) queue depth, so a trajectory artifact can see
    // a starving queue or an idle fleet at a glance.
    o.set(
        "workers",
        Json::Arr(
            s.workers
                .iter()
                .map(|w| {
                    let mut o = Json::obj();
                    o.set("worker", w.worker.into())
                        .set("batches", w.batches.into())
                        .set("images", w.images.into())
                        .set("busy_frac", w.busy_frac.into());
                    o
                })
                .collect(),
        ),
    );
    o.set(
        "queues",
        Json::Arr(
            s.queues
                .iter()
                .map(|q| {
                    let mut o = Json::obj();
                    o.set("model", q.model.as_str().into())
                        .set("engine", q.engine.into())
                        .set("generation", q.generation.into())
                        .set("queued", q.queued.into())
                        .set("capacity", q.capacity.into())
                        .set("weight", q.weight.into())
                        .set("inflight", q.inflight.into())
                        .set("closed", q.closed.into());
                    o
                })
                .collect(),
        ),
    );
    if let Some(p) = proc_obj() {
        o.set("proc", p);
    }
    o
}

fn model_stats_obj(m: &crate::coordinator::ModelStatsSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("model", m.model.as_str().into())
        .set("generation", m.generation.into())
        .set("loaded", m.loaded.into())
        .set("default", m.is_default.into())
        .set("completed", m.completed.into())
        .set("images", m.images.into())
        .set("rejected", m.rejected.into())
        .set("cache_hits", m.cache_hits.into())
        .set("cache_misses", m.cache_misses.into())
        // Cold-start economics (DESIGN.md §11): last generation build
        // wall time plus the snapshot/prefetch counters behind it.
        .set("warm_ms", m.warm_ms.into())
        .set("snapshot_hits", m.snapshot_hits.into())
        .set("snapshot_misses", m.snapshot_misses.into())
        .set("snapshot_fallbacks", m.snapshot_fallbacks.into())
        .set("prefetch_builds", m.prefetch_builds.into());
    o
}

/// `{"cmd":"models"}` reply: the registry listing.
pub fn models_line(default_model: &str, models: &[crate::coordinator::ModelStatsSnapshot]) -> String {
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("default", default_model.into())
        .set(
            "models",
            Json::Arr(models.iter().map(model_stats_obj).collect()),
        );
    o.to_string()
}

/// `{"cmd":"reload"}` success reply.  `rebuilt:false` marks a no-op
/// reload: artifacts' content hash was unchanged, so the registry
/// bumped the generation counter without a probe build.
pub fn reload_line(r: &crate::registry::ReloadReport) -> String {
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("model", r.model.as_str().into())
        .set("generation", r.generation.into())
        .set("warm_ms", r.warm_ms.into())
        .set("rebuilt", r.rebuilt.into());
    o.to_string()
}

fn pools_arr(pools: &[crate::policy::PoolSnapshot]) -> Json {
    Json::Arr(
        pools
            .iter()
            .map(|pool| {
                let mut o = Json::obj();
                o.set("engine", pool.engine.into())
                    .set("workers", pool.workers.into())
                    .set("queued", pool.queued.into())
                    .set("capacity", pool.capacity.into())
                    .set("predicted_ms", pool.predicted_ms.into())
                    .set("samples", pool.samples.into());
                o
            })
            .collect(),
    )
}

fn cache_obj(c: &crate::policy::CacheStats) -> Json {
    let mut o = Json::obj();
    o.set("hits", c.hits.into())
        .set("misses", c.misses.into())
        .set("len", c.len.into())
        .set("capacity", c.capacity.into());
    o
}

/// `{"cmd":"policy"}` reply: per-pool predictions + cache + shed counts.
/// Top-level `pools`/`cache` mirror the default model; `models` is the
/// full per-model table (each row its own pools/cache — policy state is
/// namespaced by model).
pub fn policy_line(p: &PolicySnapshot) -> String {
    let models = Json::Arr(
        p.models
            .iter()
            .map(|m| {
                let mut o = Json::obj();
                o.set("model", m.model.as_str().into())
                    .set("generation", m.generation.into())
                    .set("loaded", m.loaded.into())
                    .set("pools", pools_arr(&m.pools))
                    .set("cache", cache_obj(&m.cache))
                    .set("shed_predicted", m.shed_predicted.into())
                    .set("shed_expired", m.shed_expired.into());
                o
            })
            .collect(),
    );
    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("adaptive", p.adaptive.into())
        .set("pools", pools_arr(&p.pools))
        .set("cache", cache_obj(&p.cache))
        .set("shed_predicted", p.shed_predicted.into())
        .set("shed_expired", p.shed_expired.into())
        .set("models", models);
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_infer_synthetic() {
        let m = parse_request(r#"{"id": 7, "image": {"synthetic": 42}}"#).unwrap();
        assert_eq!(
            m,
            ClientMsg::Infer {
                id: 7,
                image: ImageSpec::Synthetic(42),
                slo: Slo::default(),
                model: None,
            }
        );
    }

    #[test]
    fn parse_model_field() {
        let m = parse_request(
            r#"{"id":7,"image":{"synthetic":42},"model":"squeezenet-v2"}"#,
        )
        .unwrap();
        match m {
            ClientMsg::Infer { model, .. } => {
                assert_eq!(model.as_deref(), Some("squeezenet-v2"))
            }
            other => panic!("expected infer, got {other:?}"),
        }
        // Malformed model must be a parse error, never a silent default.
        assert!(parse_request(r#"{"id":1,"image":{"synthetic":1},"model":7}"#)
            .is_err());
        assert!(parse_request(r#"{"id":1,"image":{"synthetic":1},"model":""}"#)
            .is_err());
    }

    #[test]
    fn parse_reload_and_models_cmds() {
        assert_eq!(
            parse_request(r#"{"cmd":"models"}"#).unwrap(),
            ClientMsg::Models
        );
        assert_eq!(
            parse_request(r#"{"cmd":"reload"}"#).unwrap(),
            ClientMsg::Reload { model: None }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"reload","model":"b"}"#).unwrap(),
            ClientMsg::Reload {
                model: Some("b".to_string())
            }
        );
        assert!(parse_request(r#"{"cmd":"reload","model":3}"#).is_err());
    }

    #[test]
    fn parse_infer_ppm() {
        let m = parse_request(r#"{"id":1,"image":{"ppm":"/tmp/x.ppm"}}"#).unwrap();
        assert!(matches!(
            m,
            ClientMsg::Infer { image: ImageSpec::Ppm(_), .. }
        ));
    }

    #[test]
    fn parse_slo_fields() {
        let m = parse_request(
            r#"{"id":7,"image":{"synthetic":1},"deadline_ms":250,"priority":"hi"}"#,
        )
        .unwrap();
        match m {
            ClientMsg::Infer { slo, .. } => {
                assert_eq!(slo.deadline, Some(Duration::from_millis(250)));
                assert_eq!(slo.priority, Priority::Hi);
            }
            other => panic!("expected infer, got {other:?}"),
        }
    }

    #[test]
    fn parse_rejects_bad_slo() {
        assert!(parse_request(
            r#"{"id":1,"image":{"synthetic":1},"deadline_ms":-5}"#
        )
        .is_err());
        // Absurd deadlines are rejected rather than panicking the
        // connection thread in Duration::from_secs_f64.
        assert!(parse_request(
            r#"{"id":1,"image":{"synthetic":1},"deadline_ms":1e30}"#
        )
        .is_err());
        assert!(parse_request(
            r#"{"id":1,"image":{"synthetic":1},"deadline_ms":"fast"}"#
        )
        .is_err());
        assert!(parse_request(
            r#"{"id":1,"image":{"synthetic":1},"priority":"urgent"}"#
        )
        .is_err());
    }

    #[test]
    fn parse_requires_integer_id() {
        // Missing id must not silently default to 0 — reply routing is
        // keyed on it.
        let e = parse_request(r#"{"image":{"synthetic":1}}"#).unwrap_err();
        assert!(e.to_string().contains("id"), "{e}");
        assert!(parse_request(r#"{"id":"seven","image":{"synthetic":1}}"#).is_err());
        assert!(parse_request(r#"{"id":-3,"image":{"synthetic":1}}"#).is_err());
        assert!(parse_request(r#"{"id":1.5,"image":{"synthetic":1}}"#).is_err());
        // Integer-valued floats are fine (JSON has one number type).
        assert!(parse_request(r#"{"id":7.0,"image":{"synthetic":1}}"#).is_ok());
    }

    #[test]
    fn parse_hello_negotiation() {
        assert_eq!(
            parse_request(r#"{"cmd":"hello"}"#).unwrap(),
            ClientMsg::Hello {
                binary_frames: false
            }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"hello","features":{"binary_frames":true}}"#)
                .unwrap(),
            ClientMsg::Hello {
                binary_frames: true
            }
        );
        // Unknown requested features are ignored, not rejected: the
        // client learns what it got from the reply's negotiated set.
        assert_eq!(
            parse_request(r#"{"cmd":"hello","features":{"quantum_lane":true}}"#)
                .unwrap(),
            ClientMsg::Hello {
                binary_frames: false
            }
        );
        // A malformed opt-in is a parse error, never a silent false.
        assert!(
            parse_request(r#"{"cmd":"hello","features":{"binary_frames":1}}"#)
                .is_err()
        );
    }

    #[test]
    fn parse_frame_header() {
        let m = parse_request(
            r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":"u8"}}}"#,
        )
        .unwrap();
        match m {
            ClientMsg::Infer { image, .. } => {
                assert_eq!(
                    image,
                    ImageSpec::Frame(FrameHeader {
                        len: 12,
                        h: 2,
                        w: 2,
                        c: 3,
                        dtype: "u8".to_string(),
                    })
                );
                assert_eq!(wire_key(&image), None, "frames are never wire-keyed");
            }
            other => panic!("expected infer, got {other:?}"),
        }
        // dtype defaults to u8; dims are mandatory integers.
        let m = parse_request(r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3}}}"#)
            .unwrap();
        match m {
            ClientMsg::Infer {
                image: ImageSpec::Frame(h),
                ..
            } => assert_eq!(h.dtype, "u8"),
            other => panic!("expected frame infer, got {other:?}"),
        }
        assert!(parse_request(r#"{"id":1,"image":{"frame":{"h":2,"w":2,"c":3}}}"#).is_err());
        assert!(parse_request(
            r#"{"id":1,"image":{"frame":{"len":-1,"h":2,"w":2,"c":3}}}"#
        )
        .is_err());
        assert!(parse_request(
            r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":7}}}"#
        )
        .is_err());
        // Unsupported dtype *strings* parse fine — the plane rejects
        // them as bad_frame so it can still resync past the payload.
        assert!(parse_request(
            r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":"f32"}}}"#
        )
        .is_ok());
    }

    #[test]
    fn frame_header_check_covers_every_reject() {
        let ok = FrameHeader {
            len: 12,
            h: 2,
            w: 2,
            c: 3,
            dtype: "u8".into(),
        };
        assert!(ok.check(1024).is_ok());
        assert!(ok.resyncable(1024));
        // Oversize: not even resyncable under the budget.
        assert!(ok.check(11).unwrap_err().contains("max-frame-bytes"));
        assert!(!ok.resyncable(11));
        let bad_dtype = FrameHeader {
            dtype: "f32".into(),
            ..ok.clone()
        };
        assert!(bad_dtype.check(1024).unwrap_err().contains("dtype"));
        assert!(bad_dtype.resyncable(1024), "dtype reject can still resync");
        let bad_c = FrameHeader { c: 4, ..ok.clone() };
        assert!(bad_c.check(1024).unwrap_err().contains("c must be 3"));
        let mismatch = FrameHeader { h: 3, ..ok.clone() };
        assert!(mismatch.check(1024).unwrap_err().contains("h*w*c"));
        let zero = FrameHeader {
            h: 0,
            ..ok.clone()
        };
        assert!(zero.check(1024).is_err());
        // Overflow in h*w*c must reject, not wrap.
        let huge = FrameHeader {
            len: 12,
            h: usize::MAX,
            w: 2,
            c: 3,
            dtype: "u8".into(),
        };
        assert!(huge.check(usize::MAX).is_err());
    }

    #[test]
    fn hello_line_advertises_version_and_features() {
        let j = Json::parse(&hello_line("event", "tape", true)).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.usize_of("protocol_version").unwrap(), 1);
        let feats: Vec<&str> = j
            .get("features")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|f| f.as_str())
            .collect();
        assert!(feats.contains(&"binary_frames"));
        assert!(feats.contains(&"wire_parser:tape"));
        assert!(feats.contains(&"plane:event"));
        assert_eq!(
            j.get("negotiated").unwrap().get("binary_frames").unwrap().as_bool(),
            Some(true)
        );
        let j = Json::parse(&hello_line("threads", "tree", false)).unwrap();
        assert_eq!(
            j.get("negotiated").unwrap().get("binary_frames").unwrap().as_bool(),
            Some(false)
        );
    }

    #[test]
    fn error_lines_carry_unified_schema() {
        // {ok:false, id, kind, msg} on every reject shape.  The
        // deprecated "error" alias is off the default wire; it only
        // reappears under --compat-error-alias, duplicating msg.
        for fmt in [ReplyFmt::default(), ReplyFmt::new(true)] {
            for line in [
                fmt.error_line(1, "boom"),
                fmt.error_line_kind(2, "bad_frame", "frame len 0 outside (0, 8]"),
                fmt.error_line_kind(3, "unsupported_feature", "negotiate first"),
                fmt.shed_line(4, 412.0, 250.0),
            ] {
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
                let kind = j.str_of("kind").unwrap();
                assert!(ERROR_KINDS.contains(&kind), "unlisted kind {kind}");
                let msg = j.str_of("msg").unwrap();
                assert!(!msg.is_empty());
                if fmt.error_alias {
                    assert_eq!(j.str_of("error").unwrap(), msg, "alias must match msg");
                } else {
                    assert!(j.get("error").is_none(), "alias leaked into {line}");
                }
            }
        }
    }

    #[test]
    fn wire_key_only_for_self_describing_specs() {
        let a = wire_key(&ImageSpec::Synthetic(42));
        let b = wire_key(&ImageSpec::Synthetic(42));
        let c = wire_key(&ImageSpec::Synthetic(43));
        assert!(a.is_some());
        assert_eq!(a, b, "same seed must key identically");
        assert_ne!(a, c, "different seeds must not collide");
        assert_eq!(wire_key(&ImageSpec::Ppm("/tmp/x.ppm".into())), None);
    }

    /// Both parsers over one line: agree on accept/reject; on accept the
    /// messages and wire keys are equal; on reject the error text is
    /// byte-identical (the tape defers its message to the tree parser).
    fn assert_parsers_agree(line: &[u8]) {
        let mut tape = WireTape::new();
        let tree = parse_line(WireParser::Tree, line, &mut tape);
        let tap = parse_line(WireParser::Tape, line, &mut tape);
        match (tree, tap) {
            (Ok((m1, k1)), Ok((m2, k2))) => {
                assert_eq!(m1, m2, "message mismatch on {:?}", String::from_utf8_lossy(line));
                assert_eq!(k1, k2, "wire key mismatch on {:?}", String::from_utf8_lossy(line));
            }
            (Err(e1), Err(e2)) => {
                assert_eq!(
                    e1.to_string(),
                    e2.to_string(),
                    "error text mismatch on {:?}",
                    String::from_utf8_lossy(line)
                );
            }
            (t, p) => panic!(
                "accept/reject mismatch on {:?}: tree={:?} tape={:?}",
                String::from_utf8_lossy(line),
                t.is_ok(),
                p.is_ok()
            ),
        }
    }

    #[test]
    fn tape_matches_tree_on_the_request_corpus() {
        let corpus: &[&[u8]] = &[
            br#"{"id": 7, "image": {"synthetic": 42}}"#,
            br#"{"id":1,"image":{"ppm":"/tmp/x.ppm"}}"#,
            br#"{"id":7,"image":{"synthetic":1},"deadline_ms":250,"priority":"hi"}"#,
            br#"{"id":7,"image":{"synthetic":42},"model":"squeezenet-v2"}"#,
            br#"{"id":7.0,"image":{"synthetic":1}}"#,
            br#"{"id":1,"id":2,"image":{"synthetic":1}}"#,
            br#"  {"cmd":"stats"}  "#,
            br#"{"cmd":"metrics"}"#,
            br#"{"cmd":"trace"}"#,
            br#"{"cmd":"trace","n":5}"#,
            br#"{"cmd":"trace","n":1000000}"#,
            br#"{"cmd":"trace","n":0}"#,
            br#"{"cmd":"trace","n":"many"}"#,
            br#"{"cmd":"policy"}"#,
            br#"{"cmd":"models"}"#,
            br#"{"cmd":"reload"}"#,
            br#"{"cmd":"reload","model":"b"}"#,
            br#"{"cmd":"reload","model":3}"#,
            br#"{"cmd":"ping"}"#,
            br#"{"cmd":"hello"}"#,
            br#"{"cmd":"hello","features":{"binary_frames":true}}"#,
            br#"{"cmd":"hello","features":{"binary_frames":false}}"#,
            br#"{"cmd":"hello","features":{"binary_frames":1}}"#,
            br#"{"cmd":"hello","features":{"quantum_lane":true}}"#,
            br#"{"cmd":"hello","features":7}"#,
            br#"{"cmd":"hello","features":["binary_frames"]}"#,
            br#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3}}}"#,
            br#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":"u8"}}}"#,
            br#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":"f32"}}}"#,
            br#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":7}}}"#,
            br#"{"id":1,"image":{"frame":{"h":2,"w":2,"c":3}}}"#,
            br#"{"id":1,"image":{"frame":{"len":-1,"h":2,"w":2,"c":3}}}"#,
            br#"{"id":1,"image":{"frame":{"len":1.5,"h":2,"w":2,"c":3}}}"#,
            br#"{"id":1,"image":{"frame":7}}"#,
            br#"{"id":1,"image":{"frame":{}}}"#,
            br#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3}},"deadline_ms":250,"priority":"hi","model":"m"}"#,
            br#"{"id":1,"image":{"synthetic":5,"frame":{"len":12,"h":2,"w":2,"c":3}}}"#,
            br#"{"cmd":"reboot"}"#,
            br#"{"cmd":7,"id":1,"image":{"synthetic":1}}"#,
            br#"{"id":7,"image":{"synthetic":1}}"#,
            b"not json",
            br#"{"id":1}"#,
            br#"{"id":1,"image":{}}"#,
            br#"{"id":1,"image":7}"#,
            br#"{"image":{"synthetic":1}}"#,
            br#"{"id":"seven","image":{"synthetic":1}}"#,
            br#"{"id":-3,"image":{"synthetic":1}}"#,
            br#"{"id":1.5,"image":{"synthetic":1}}"#,
            br#"{"id":1,"image":{"synthetic":1},"deadline_ms":-5}"#,
            br#"{"id":1,"image":{"synthetic":1},"deadline_ms":1e30}"#,
            br#"{"id":1,"image":{"synthetic":1},"deadline_ms":"fast"}"#,
            br#"{"id":1,"image":{"synthetic":1},"priority":"urgent"}"#,
            br#"{"id":1,"image":{"synthetic":1},"priority":7}"#,
            br#"{"id":1,"image":{"synthetic":1},"model":7}"#,
            br#"{"id":1,"image":{"synthetic":1},"model":""}"#,
            br#"{"id":1,"image":{"synthetic":1},"model":"a\nb"}"#,
            b"{\"id\":1,\"image\":{\"synthetic\":1},\"model\":\"a\xffb\"}",
            b"",
            b"   ",
            b"{\"id\":1,",
        ];
        for line in corpus {
            assert_parsers_agree(line);
        }
    }

    #[test]
    fn tape_wire_key_matches_tree_across_number_spellings() {
        // Every spelling of a seed must land on the key the tree path
        // computes from the parsed spec — canonical spans hash in place,
        // everything else is re-formatted first.
        let cases: &[(&str, u64)] = &[
            ("42", 42),
            ("4.2e1", 42),
            ("042", 42),
            ("0", 0),
            ("-5", 0),                              // saturating cast
            ("9007199254740993", 9007199254740992), // 16 digits: f64-rounded
            ("18446744073709551615", u64::MAX),
            ("1e309", u64::MAX), // inf saturates
        ];
        let mut tape = WireTape::new();
        for (spelling, seed) in cases {
            let line = format!(r#"{{"id":1,"image":{{"synthetic":{spelling}}}}}"#);
            let (msg, key) =
                parse_line(WireParser::Tape, line.as_bytes(), &mut tape).unwrap();
            match msg {
                ClientMsg::Infer { image, .. } => {
                    assert_eq!(image, ImageSpec::Synthetic(*seed), "seed of {spelling}");
                    assert_eq!(
                        key,
                        wire_key(&ImageSpec::Synthetic(*seed)),
                        "key of {spelling}"
                    );
                }
                other => panic!("expected infer, got {other:?}"),
            }
        }
    }

    #[test]
    fn tape_rejects_deep_nesting_with_a_structured_error() {
        // 100k opens: the iterative scanner rejects at MAX_DEPTH; the
        // (bounded) tree parser supplies the error text.
        let mut line = r#"{"id":"#.to_string();
        line.push_str(&"[".repeat(100_000));
        let mut tape = WireTape::new();
        let e = ClientMsg::parse_tape(line.as_bytes(), &mut tape).unwrap_err();
        assert!(e.to_string().contains("depth"), "{e}");
        assert_parsers_agree(line.as_bytes());
    }

    #[test]
    fn parse_cmds() {
        assert_eq!(parse_request(r#"{"cmd":"stats"}"#).unwrap(), ClientMsg::Stats);
        assert_eq!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), ClientMsg::Ping);
        assert_eq!(
            parse_request(r#"{"cmd":"policy"}"#).unwrap(),
            ClientMsg::Policy
        );
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id":1}"#).is_err());
        assert!(parse_request(r#"{"id":1,"image":{}}"#).is_err());
        assert!(parse_request(r#"{"cmd":"reboot"}"#).is_err());
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = Response {
            id: 3,
            top1: 694,
            top5: vec![(694, 0.5), (1, 0.25)],
            queue_ms: 0.5,
            exec_ms: 100.0,
            total_ms: 101.0,
            batch_size: 2,
            worker: 0,
            engine: "acl",
            model: std::sync::Arc::from("squeezenet"),
            cached: false,
            kind: "",
            error: None,
            span: None,
        };
        let line = response_line(&r);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.usize_of("top1").unwrap(), 694);
        assert_eq!(j.usize_of("batch").unwrap(), 2);
        assert_eq!(j.str_of("engine").unwrap(), "acl");
        assert_eq!(j.str_of("model").unwrap(), "squeezenet");
        assert_eq!(j.get("cached").unwrap().as_bool(), Some(false));
        let err = error_line(9, "overloaded");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn queue_expiry_response_carries_shed_kind() {
        let r = Response::shed_expired(5, crate::coordinator::worker::DEADLINE_ERROR);
        let j = Json::parse(&response_line(&r)).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.str_of("kind").unwrap(), "shed");
        assert!(j.str_of("msg").unwrap().contains("deadline"));
        assert!(j.get("error").is_none(), "alias is off the default wire");
        // The compat formatter restores the alias for old clients.
        let j = Json::parse(&ReplyFmt::new(true).response_line(&r)).unwrap();
        assert!(j.str_of("error").unwrap().contains("deadline"));
    }

    #[test]
    fn parse_metrics_and_trace_cmds() {
        assert_eq!(
            parse_request(r#"{"cmd":"metrics"}"#).unwrap(),
            ClientMsg::Metrics
        );
        assert_eq!(
            parse_request(r#"{"cmd":"trace"}"#).unwrap(),
            ClientMsg::Trace { n: 32 }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"trace","n":5}"#).unwrap(),
            ClientMsg::Trace { n: 5 }
        );
        // Clamped, not rejected: the rings are bounded anyway.
        assert_eq!(
            parse_request(r#"{"cmd":"trace","n":1000000}"#).unwrap(),
            ClientMsg::Trace { n: 4096 }
        );
        assert!(parse_request(r#"{"cmd":"trace","n":0}"#).is_err());
        assert!(parse_request(r#"{"cmd":"trace","n":"many"}"#).is_err());
    }

    #[test]
    fn span_serializes_marks_and_flags() {
        use crate::obs::{flag, Span, Stage};
        let mut s = Span {
            id: 9,
            deadline_ns: 250_000_000,
            flags: flag::SAMPLED | flag::DEADLINE_MISSED,
            ..Span::default()
        };
        s.set(Stage::Accepted, 1_000_000);
        s.set(Stage::Parsed, 1_500_000);
        s.set(Stage::ReplyFlushed, 301_000_000);
        let j = Json::parse(&trace_line(&[s], &[])).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        let t = &j.get("traces").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.usize_of("id").unwrap(), 9);
        assert_eq!(t.f64_of("deadline_ms").unwrap(), 250.0);
        let marks = t.get("marks").unwrap();
        // Offsets are relative to the first stamped stage.
        assert_eq!(marks.f64_of("accepted").unwrap(), 0.0);
        assert_eq!(marks.f64_of("parsed").unwrap(), 0.5);
        assert_eq!(marks.f64_of("reply_flushed").unwrap(), 300.0);
        assert!(marks.get("dequeued").is_none(), "unset stages are omitted");
        let flags: Vec<&str> = t
            .get("flags")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|f| f.as_str())
            .collect();
        assert!(flags.contains(&"sampled"));
        assert!(flags.contains(&"deadline_missed"));
        assert_eq!(j.get("slow").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn stats_line_carries_proc_section() {
        let s = crate::coordinator::StatsSnapshot::default();
        let j = Json::parse(&stats_line(&s)).unwrap();
        let p = j.get("proc").expect("proc section (Linux host)");
        assert!(p.f64_of("rss_mb").unwrap() > 0.0);
        assert!(p.usize_of("open_fds").unwrap() >= 3);
        assert!(p.f64_of("uptime_s").unwrap() >= 0.0);
    }

    #[test]
    fn shed_line_is_structured() {
        let j = Json::parse(&shed_line(4, 412.0, 250.0)).unwrap();
        assert_eq!(j.str_of("kind").unwrap(), "shed");
        assert_eq!(j.f64_of("predicted_ms").unwrap(), 412.0);
        assert_eq!(j.f64_of("deadline_ms").unwrap(), 250.0);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }
}
