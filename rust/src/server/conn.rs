//! Connection-plane plumbing shared by both serving planes: pooled
//! byte buffers, bounded newline framing over partial reads, a write
//! buffer with backpressure watermarks, and the accept-error backoff
//! policy.  Everything here is pure state-machine code — no sockets —
//! so the invariants the reactor leans on are unit-testable without a
//! kernel in the loop.

use std::io;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Pool of reusable byte buffers for connection read/write state.
/// Ten thousand connections each holding two `Vec`s would otherwise
/// churn the allocator on every connect/disconnect cycle; the pool
/// bounds retention (`retain` buffers) so an idle server shrinks back.
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    retain: usize,
    init_capacity: usize,
    outstanding: AtomicUsize,
}

/// Occupancy snapshot for `{"cmd":"stats"}`.
#[derive(Debug, Clone, Copy, Default)]
pub struct BufPoolStats {
    /// Buffers sitting in the free list.
    pub free: usize,
    /// Buffers currently held by live connections.
    pub outstanding: usize,
}

impl BufPool {
    pub fn new(retain: usize, init_capacity: usize) -> BufPool {
        BufPool {
            free: Mutex::new(Vec::new()),
            retain,
            init_capacity,
            outstanding: AtomicUsize::new(0),
        }
    }

    pub fn take(&self) -> Vec<u8> {
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        self.free
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(self.init_capacity))
    }

    pub fn put(&self, mut buf: Vec<u8>) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
        buf.clear();
        // A buffer that ballooned (one huge request) is not worth
        // retaining — keeping it would pin the high-water mark forever.
        if buf.capacity() > self.init_capacity * 8 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        if free.len() < self.retain {
            free.push(buf);
        }
    }

    pub fn stats(&self) -> BufPoolStats {
        BufPoolStats {
            free: self.free.lock().unwrap().len(),
            outstanding: self.outstanding.load(Ordering::Relaxed),
        }
    }
}

/// Framing error: the client exceeded the per-line byte budget.
#[derive(Debug, PartialEq, Eq)]
pub struct Oversize {
    /// Bytes accumulated when the bound tripped.
    pub seen: usize,
}

/// Drain every complete newline-terminated line out of `rbuf`, leaving
/// any trailing partial line in place for the next read.
///
/// Enforces `max_line_bytes` two ways: a *complete* line longer than
/// the bound, or a newline-less residue that has already outgrown it
/// (the streaming-OOM case), both return [`Oversize`] — the caller
/// answers `bad_request` and closes.  Lines are lossily UTF-8 decoded;
/// invalid bytes simply fail JSON parsing downstream, which keeps the
/// error path uniform (a structured reject, not a dropped connection).
pub fn drain_lines(rbuf: &mut Vec<u8>, max_line_bytes: usize) -> Result<Vec<String>, Oversize> {
    let mut lines = Vec::new();
    let mut start = 0usize;
    while let Some(pos) = rbuf[start..].iter().position(|&b| b == b'\n') {
        let end = start + pos;
        if end - start > max_line_bytes {
            return Err(Oversize { seen: end - start });
        }
        let line = String::from_utf8_lossy(&rbuf[start..end]).into_owned();
        lines.push(line);
        start = end + 1;
    }
    if rbuf.len() - start > max_line_bytes {
        return Err(Oversize {
            seen: rbuf.len() - start,
        });
    }
    rbuf.drain(..start);
    Ok(lines)
}

/// Find the next complete newline-terminated line in `rbuf` starting at
/// `start`, as a byte range (newline excluded) — the zero-copy sibling
/// of [`drain_lines`].  The wire plane parses straight over the span in
/// the pooled read buffer, so framing allocates nothing per line.
///
/// Same `max_line_bytes` contract as [`drain_lines`]: a complete line
/// over the bound, or a newline-less residue that has already outgrown
/// it, is [`Oversize`].  `Ok(None)` means no complete line yet — the
/// caller drains `..start` and waits for the next read.
pub fn next_line_span(
    rbuf: &[u8],
    start: usize,
    max_line_bytes: usize,
) -> Result<Option<std::ops::Range<usize>>, Oversize> {
    let rest = rbuf.get(start..).unwrap_or(&[]);
    match rest.iter().position(|&b| b == b'\n') {
        Some(pos) => {
            if pos > max_line_bytes {
                return Err(Oversize { seen: pos });
            }
            Ok(Some(start..start + pos))
        }
        None => {
            if rest.len() > max_line_bytes {
                return Err(Oversize { seen: rest.len() });
            }
            Ok(None)
        }
    }
}

/// One complete item framed off the wire, as a byte range into the
/// pooled read buffer (zero-copy, like [`next_line_span`]).
#[derive(Debug, PartialEq, Eq)]
pub enum WireItem {
    /// A newline-terminated JSON line (newline excluded).
    Line(std::ops::Range<usize>),
    /// A binary frame payload: exactly the byte count a preceding
    /// request line declared via `"image":{"frame":{"len":N,..}}`.
    Frame(std::ops::Range<usize>),
}

/// Per-connection framing mode: newline-delimited JSON lines, or —
/// after a request line declared a binary frame — exactly N raw
/// payload bytes before line mode resumes.
///
/// The mode switch is driven by the protocol layer (only it knows a
/// line declared a frame); this type owns the byte-level state machine
/// both planes share: the reactor feeds it the pooled read buffer, the
/// threads plane drives it over a blocking `BufReader`.  A connection
/// that never negotiates frames never leaves line mode, so plain JSON
/// clients are byte-for-byte unaffected.
#[derive(Debug, Default)]
pub struct Framing {
    expecting: Option<usize>,
}

impl Framing {
    pub fn new() -> Framing {
        Framing { expecting: None }
    }

    /// Switch to payload mode: the next `n` wire bytes are one binary
    /// frame, not line data.  `n` must already be validated against
    /// `max_frame_bytes` — the framing layer trusts it so that it
    /// never needs its own oversize path.
    pub fn expect_payload(&mut self, n: usize) {
        debug_assert!(self.expecting.is_none(), "frame declared inside a frame");
        self.expecting = Some(n);
    }

    /// Payload bytes still owed before line mode resumes.
    pub fn expecting(&self) -> Option<usize> {
        self.expecting
    }

    /// Frame the next complete item out of `rbuf` at `start`.
    ///
    /// In line mode this is exactly [`next_line_span`] (same
    /// `max_line_bytes` / [`Oversize`] contract).  In payload mode it
    /// returns a `Frame` span once all expected bytes are buffered and
    /// switches back to line mode; `Ok(None)` means a partial payload
    /// — the caller keeps the tail and waits for the next read.
    pub fn next_item(
        &mut self,
        rbuf: &[u8],
        start: usize,
        max_line_bytes: usize,
    ) -> Result<Option<WireItem>, Oversize> {
        match self.expecting {
            Some(n) => {
                if rbuf.len().saturating_sub(start) < n {
                    return Ok(None);
                }
                self.expecting = None;
                Ok(Some(WireItem::Frame(start..start + n)))
            }
            None => Ok(next_line_span(rbuf, start, max_line_bytes)?.map(WireItem::Line)),
        }
    }
}

/// Buffered writer for a non-blocking socket with watermark-based
/// backpressure.
///
/// Replies are appended whole; `flush` pushes as much as the socket
/// accepts and reports whether bytes remain (the caller then arms
/// EPOLLOUT).  When the backlog crosses `high` the connection should
/// stop *reading* (a pipelining client that never drains replies must
/// not grow this buffer without bound); reading resumes once the
/// backlog falls to `high / 4`.
pub struct WriteBuf {
    buf: Vec<u8>,
    start: usize,
    high: usize,
}

impl WriteBuf {
    pub fn new(buf: Vec<u8>, high: usize) -> WriteBuf {
        WriteBuf {
            buf,
            start: 0,
            high,
        }
    }

    /// Append one reply line (the newline is added here so callers
    /// can't forget it).
    pub fn push_line(&mut self, line: &str) {
        self.buf.extend_from_slice(line.as_bytes());
        self.buf.push(b'\n');
    }

    /// Append one reply line followed by a raw binary payload — the
    /// write-side mirror of [`Framing`], so future replies can carry
    /// tensors the same way requests carry frames.
    pub fn push_frame(&mut self, line: &str, payload: &[u8]) {
        self.push_line(line);
        self.buf.extend_from_slice(payload);
    }

    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Above the high watermark: pause reads on this connection.
    pub fn over_high(&self) -> bool {
        self.pending() > self.high
    }

    /// At/below the low watermark: a paused connection may read again.
    pub fn under_low(&self) -> bool {
        self.pending() <= self.high / 4
    }

    /// Write as much as the socket will take.  `Ok(true)` means fully
    /// drained; `Ok(false)` means the socket is full (arm EPOLLOUT and
    /// retry on writability).
    pub fn flush(&mut self, w: &mut impl io::Write) -> io::Result<bool> {
        while self.start < self.buf.len() {
            match w.write(&self.buf[self.start..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket accepted 0 bytes",
                    ))
                }
                Ok(n) => self.start += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    self.compact();
                    return Ok(false);
                }
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.start = 0;
        Ok(true)
    }

    /// Reclaim the consumed prefix once it dominates the buffer, so a
    /// long-lived slow reader doesn't hold its entire reply history.
    fn compact(&mut self) {
        if self.start > 4096 && self.start * 2 >= self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// Hand the backing buffer back (for pool return on close).
    pub fn into_buf(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }
}

/// Backoff policy for transient `accept()` failures.
///
/// The pre-reactor server `break`ed out of its accept loop on any
/// error, so one EMFILE burst (fd pressure from the very connections
/// being served) permanently killed accepting while established
/// connections lived on — a silent half-dead server.  Every accept
/// error is now survivable: transient ones (fd exhaustion, aborted
/// handshakes, signals) sleep an escalating-but-capped interval and
/// retry; even unrecognized errors only log-and-retry, because a
/// listener that stops accepting is strictly worse than one that
/// retries a weird errno.
pub struct AcceptBackoff {
    step: u32,
}

impl AcceptBackoff {
    const BASE_MS: u64 = 1;
    const CAP_MS: u64 = 500;

    pub fn new() -> AcceptBackoff {
        AcceptBackoff { step: 0 }
    }

    /// Is this error kind an expected under-pressure transient?
    /// (EMFILE/ENFILE surface as `Other`/`Uncategorized` through std,
    /// so classification is by raw errno.)
    pub fn transient(e: &io::Error) -> bool {
        // EMFILE=24 ENFILE=23 ENOMEM=12 ECONNABORTED=103 EINTR=4
        // EPROTO=71 (Linux errno values; this module is linux-only).
        matches!(e.raw_os_error(), Some(24 | 23 | 12 | 103 | 4 | 71))
            || matches!(
                e.kind(),
                io::ErrorKind::ConnectionAborted
                    | io::ErrorKind::Interrupted
                    | io::ErrorKind::WouldBlock
            )
    }

    /// Next sleep before retrying: 1ms, 2ms, 4ms, ... capped at 500ms.
    pub fn next_delay(&mut self) -> Duration {
        let ms = (Self::BASE_MS << self.step.min(16)).min(Self::CAP_MS);
        self.step = self.step.saturating_add(1);
        Duration::from_millis(ms)
    }

    /// A successful accept ends the incident: start fresh next time.
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

impl Default for AcceptBackoff {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- framing ------------------------------------------------------------

    #[test]
    fn drains_complete_lines_keeps_partial_tail() {
        let mut b = b"{\"a\":1}\n{\"b\":2}\n{\"part".to_vec();
        let lines = drain_lines(&mut b, 1024).unwrap();
        assert_eq!(lines, vec!["{\"a\":1}", "{\"b\":2}"]);
        assert_eq!(b, b"{\"part");
        // The tail completes on the next read.
        b.extend_from_slice(b"ial\":3}\n");
        let lines = drain_lines(&mut b, 1024).unwrap();
        assert_eq!(lines, vec!["{\"partial\":3}"]);
        assert!(b.is_empty());
    }

    #[test]
    fn oversize_newlineless_stream_is_rejected() {
        // The OOM-DoS shape: bytes forever, never a newline.
        let mut b = vec![b'x'; 100];
        let err = drain_lines(&mut b, 64).unwrap_err();
        assert_eq!(err.seen, 100);
    }

    #[test]
    fn oversize_complete_line_is_rejected_too() {
        // A newline *within* the read chunk must not smuggle an
        // over-budget line past the bound.
        let mut b = vec![b'y'; 100];
        b.push(b'\n');
        b.extend_from_slice(b"{\"ok\":1}\n");
        assert!(drain_lines(&mut b, 64).is_err());
    }

    #[test]
    fn line_exactly_at_bound_passes() {
        let mut b = vec![b'z'; 64];
        b.push(b'\n');
        let lines = drain_lines(&mut b, 64).unwrap();
        assert_eq!(lines[0].len(), 64);
    }

    #[test]
    fn invalid_utf8_becomes_a_parseable_reject_not_a_panic() {
        let mut b = vec![0xFF, 0xFE, b'\n'];
        let lines = drain_lines(&mut b, 64).unwrap();
        assert_eq!(lines.len(), 1);
        assert!(crate::server::protocol::parse_request(&lines[0]).is_err());
    }

    #[test]
    fn next_line_span_mirrors_drain_lines() {
        let b = b"{\"a\":1}\n{\"b\":2}\n{\"part";
        let s1 = next_line_span(b, 0, 1024).unwrap().expect("first line");
        assert_eq!(&b[s1.clone()], b"{\"a\":1}");
        let s2 = next_line_span(b, s1.end + 1, 1024).unwrap().expect("second line");
        assert_eq!(&b[s2.clone()], b"{\"b\":2}");
        // Partial tail: no span, not an error (waits for more bytes).
        assert_eq!(next_line_span(b, s2.end + 1, 1024).unwrap(), None);
        // Oversize complete line and oversize newline-less residue both
        // reject, exactly like drain_lines.
        let mut big = vec![b'y'; 100];
        assert_eq!(next_line_span(&big, 0, 64).unwrap_err(), Oversize { seen: 100 });
        big.push(b'\n');
        assert_eq!(next_line_span(&big, 0, 64).unwrap_err(), Oversize { seen: 100 });
        // A line exactly at the bound passes.
        let mut ok = vec![b'z'; 64];
        ok.push(b'\n');
        assert_eq!(next_line_span(&ok, 0, 64).unwrap(), Some(0..64));
    }

    #[test]
    fn framing_interleaves_lines_and_payloads() {
        // line, frame header line, 8-byte payload, line — one buffer.
        let mut b = b"{\"a\":1}\n{\"hdr\":1}\n".to_vec();
        b.extend_from_slice(&[0, 1, 2, 3, 4, 5, 6, 7]);
        b.extend_from_slice(b"{\"b\":2}\n");
        let mut f = Framing::new();
        let mut start = 0usize;
        let i1 = f.next_item(&b, start, 1024).unwrap().unwrap();
        assert_eq!(i1, WireItem::Line(0..7));
        start = 8;
        let i2 = f.next_item(&b, start, 1024).unwrap().unwrap();
        let hdr = match i2 {
            WireItem::Line(r) => r,
            other => panic!("expected header line, got {other:?}"),
        };
        assert_eq!(&b[hdr.clone()], b"{\"hdr\":1}");
        start = hdr.end + 1;
        // The protocol layer saw the header and declares the payload.
        f.expect_payload(8);
        let i3 = f.next_item(&b, start, 1024).unwrap().unwrap();
        match i3 {
            WireItem::Frame(r) => {
                assert_eq!(&b[r.clone()], &[0, 1, 2, 3, 4, 5, 6, 7]);
                start = r.end;
            }
            other => panic!("expected frame, got {other:?}"),
        }
        // Back in line mode automatically.
        assert_eq!(f.expecting(), None);
        let i4 = f.next_item(&b, start, 1024).unwrap().unwrap();
        match i4 {
            WireItem::Line(r) => assert_eq!(&b[r], b"{\"b\":2}"),
            other => panic!("expected line, got {other:?}"),
        }
    }

    #[test]
    fn framing_waits_for_partial_payload() {
        let mut f = Framing::new();
        f.expect_payload(10);
        // Only 4 of 10 payload bytes arrived: wait, stay in payload mode.
        assert_eq!(f.next_item(&[9u8; 4], 0, 64).unwrap(), None);
        assert_eq!(f.expecting(), Some(10));
        // Full payload present (split across reads upstream): framed.
        assert_eq!(
            f.next_item(&[9u8; 10], 0, 64).unwrap(),
            Some(WireItem::Frame(0..10))
        );
        assert_eq!(f.expecting(), None);
    }

    #[test]
    fn framing_payload_ignores_line_budget_and_newlines() {
        // Payload bytes may contain b'\n' and exceed max_line_bytes —
        // neither splits nor rejects a frame (len was validated against
        // max_frame_bytes before entering payload mode).
        let mut f = Framing::new();
        f.expect_payload(100);
        let b = vec![b'\n'; 100];
        assert_eq!(
            f.next_item(&b, 0, 64).unwrap(),
            Some(WireItem::Frame(0..100))
        );
    }

    #[test]
    fn framing_line_mode_is_next_line_span() {
        // No negotiation, no frames: behavior is exactly next_line_span.
        let mut f = Framing::new();
        let b = b"{\"a\":1}\n{\"part";
        assert_eq!(f.next_item(b, 0, 1024).unwrap(), Some(WireItem::Line(0..7)));
        assert_eq!(f.next_item(b, 8, 1024).unwrap(), None);
        let big = vec![b'y'; 100];
        assert_eq!(f.next_item(&big, 0, 64).unwrap_err(), Oversize { seen: 100 });
    }

    // -- write buffer -------------------------------------------------------

    /// Writer that accepts `quota` bytes then reports WouldBlock, like
    /// a socket whose send buffer filled.
    struct Throttled {
        out: Vec<u8>,
        quota: usize,
    }

    impl io::Write for Throttled {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.quota == 0 {
                return Err(io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(self.quota);
            self.quota -= n;
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_write_parks_then_resumes() {
        let mut wb = WriteBuf::new(Vec::new(), 1 << 20);
        wb.push_line("hello");
        wb.push_line("world");
        let mut w = Throttled {
            out: Vec::new(),
            quota: 7,
        };
        assert!(!wb.flush(&mut w).unwrap(), "socket full: must report undrained");
        assert_eq!(w.out, b"hello\nw");
        assert_eq!(wb.pending(), 5);
        // Socket drains (EPOLLOUT): the rest goes out, buffer resets.
        w.quota = usize::MAX;
        assert!(wb.flush(&mut w).unwrap());
        assert_eq!(w.out, b"hello\nworld\n");
        assert!(wb.is_empty());
    }

    #[test]
    fn watermarks_pause_and_resume() {
        let mut wb = WriteBuf::new(Vec::new(), 100);
        assert!(!wb.over_high());
        assert!(wb.under_low());
        wb.push_line(&"x".repeat(150));
        assert!(wb.over_high(), "151 pending > 100 high");
        assert!(!wb.under_low());
        // Drain to 20 pending: 20 <= 25 (high/4) resumes reads.
        let mut w = Throttled {
            out: Vec::new(),
            quota: 131,
        };
        assert!(!wb.flush(&mut w).unwrap());
        assert_eq!(wb.pending(), 20);
        assert!(!wb.over_high());
        assert!(wb.under_low());
    }

    #[test]
    fn push_frame_appends_line_then_raw_payload() {
        let mut wb = WriteBuf::new(Vec::new(), 1 << 20);
        wb.push_frame("{\"ok\":true}", &[1, 2, 3]);
        wb.push_line("{\"next\":1}");
        let mut w = Throttled {
            out: Vec::new(),
            quota: usize::MAX,
        };
        assert!(wb.flush(&mut w).unwrap());
        assert_eq!(w.out, b"{\"ok\":true}\n\x01\x02\x03{\"next\":1}\n");
    }

    #[test]
    fn compaction_reclaims_consumed_prefix() {
        let mut wb = WriteBuf::new(Vec::new(), 1 << 20);
        wb.push_line(&"a".repeat(10_000));
        let mut w = Throttled {
            out: Vec::new(),
            quota: 9_000,
        };
        assert!(!wb.flush(&mut w).unwrap());
        // 9000 consumed of 10001: compaction dropped the dead prefix.
        assert_eq!(wb.pending(), 1_001);
        assert_eq!(wb.start, 0);
        assert_eq!(wb.buf.len(), 1_001);
    }

    // -- accept backoff -----------------------------------------------------

    #[test]
    fn backoff_escalates_caps_and_resets() {
        let mut b = AcceptBackoff::new();
        assert_eq!(b.next_delay(), Duration::from_millis(1));
        assert_eq!(b.next_delay(), Duration::from_millis(2));
        assert_eq!(b.next_delay(), Duration::from_millis(4));
        for _ in 0..20 {
            assert!(b.next_delay() <= Duration::from_millis(500), "cap holds");
        }
        assert_eq!(b.next_delay(), Duration::from_millis(500));
        b.reset();
        assert_eq!(b.next_delay(), Duration::from_millis(1));
    }

    #[test]
    fn emfile_and_friends_classify_as_transient() {
        // The regression scenario: EMFILE during fd pressure must be
        // survivable, not fatal (the old loop `break`ed on it).
        for errno in [24, 23, 12, 103, 4] {
            let e = io::Error::from_raw_os_error(errno);
            assert!(AcceptBackoff::transient(&e), "errno {errno} must be transient");
        }
        assert!(AcceptBackoff::transient(&io::ErrorKind::Interrupted.into()));
        // Unknown errors are NOT classified transient (they log louder)
        // — but the accept loop still never exits on them.
        assert!(!AcceptBackoff::transient(&io::Error::from_raw_os_error(13)));
    }

    #[test]
    fn bufpool_reuses_and_bounds_retention() {
        let p = BufPool::new(2, 64);
        let a = p.take();
        let b = p.take();
        let c = p.take();
        assert_eq!(p.stats().outstanding, 3);
        p.put(a);
        p.put(b);
        p.put(c); // third exceeds retain=2: dropped
        let s = p.stats();
        assert_eq!(s.free, 2);
        assert_eq!(s.outstanding, 0);
        // Ballooned buffers are not retained.
        let mut big = p.take();
        big.resize(64 * 16, 0);
        let cap = big.capacity();
        assert!(cap > 64 * 8);
        p.put(big);
        assert!(p.stats().free <= 2);
        let reused = p.take();
        assert!(reused.capacity() < cap, "ballooned buffer must not come back");
    }
}
