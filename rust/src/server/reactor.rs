//! Event-driven connection plane: one acceptor + a small fixed set of
//! IO threads multiplexing thousands of non-blocking connections over
//! epoll (DESIGN.md §"Connection plane").
//!
//! Ownership model:
//! - The **acceptor** owns the listening socket.  It never blocks and
//!   never exits on an accept error (see [`AcceptBackoff`]); beyond the
//!   connection cap it answers a structured `at_capacity` line before
//!   closing.  Accepted sockets are handed round-robin to an IO lane.
//! - Each **IO thread** owns one epoll instance plus every connection
//!   assigned to its lane: read buffers, write buffers, in-flight
//!   counts.  No connection state is ever touched by two threads.
//! - **Worker replies** never touch a socket: the coordinator's
//!   [`ReplySink`] serializes the response on the worker thread and
//!   pushes the finished line onto the owning lane's completion queue,
//!   waking that lane's eventfd.  The IO thread writes it out on its
//!   next turn — `(connection, request id)` in the [`CompletionToken`]
//!   is the only routing state.
//!
//! Backpressure invariant: a connection whose write backlog crosses the
//! high watermark stops being *read* until the backlog drains below
//! high/4, so a client that pipelines requests but never drains replies
//! bounds its own memory footprint instead of the server's.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{ServerConfig, WireParser};
use crate::coordinator::{
    CompletionSink, CompletionToken, Coordinator, ReplySink, SubmitError,
};
use crate::obs::{flag, ObsHub, Span, Stage};
use crate::policy::Slo;
use crate::util::log::{suppressed_note, CAPACITY_LOG};
use crate::util::wire::{self, WireTape};

use super::conn::{AcceptBackoff, BufPool, Framing, WireItem, WriteBuf};
use super::protocol::{self, ClientMsg, FrameHeader, ImageSpec};
use super::sys::{
    self, Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT,
    EPOLLRDHUP,
};
use super::{ConnPlaneSnapshot, ConnStats, PixelSource};

/// Lane index lives in the token's top bits so a completion can find
/// its owning IO thread without a lookup table.
const LANE_SHIFT: u32 = 40;
/// Epoll token of a lane's wake eventfd (never a valid conn token:
/// conn serials are masked below the lane bits).
const TOKEN_WAKE: u64 = u64::MAX;
/// Write backlog (bytes) beyond which a connection stops being read.
const WBUF_HIGH: usize = 256 * 1024;
/// Per-readiness-event read budget: chunks read before yielding to
/// other connections on the same lane (fairness under a firehose).
const READ_CHUNKS_PER_EVENT: usize = 16;

/// A finished reply line routed back to a connection.
struct Done {
    conn: u64,
    line: String,
    /// Inference completions maintain the global in-flight gauge;
    /// command completions (reload) only settle the connection.
    infer: bool,
    /// Request timeline riding along with the reply: the IO thread
    /// stamps `reply_flushed` and retires it (DESIGN.md §10).
    span: Option<Span>,
}

/// One IO thread's mailbox: new connections from the acceptor and
/// finished reply lines from workers, both signalled on one eventfd.
struct Lane {
    inbox: Mutex<Vec<(u64, TcpStream)>>,
    done: Mutex<Vec<Done>>,
    wake: EventFd,
}

/// State shared by the acceptor, the IO threads, and — through
/// [`CompletionSink`] — every in-flight request's reply path.
pub(super) struct Shared {
    stop: std::sync::atomic::AtomicBool,
    stats: ConnStats,
    pool: BufPool,
    lanes: Vec<Lane>,
    accept_wake: EventFd,
    io_threads: usize,
    max_connections: usize,
    max_line_bytes: usize,
    max_frame_bytes: usize,
    /// Request-line parser (tape hot path vs tree ablation baseline).
    wire: WireParser,
    /// Reply formatting knobs (`--compat-error-alias`).
    fmt: protocol::ReplyFmt,
    idle_timeout: Option<Duration>,
    /// Trace hub (same instance the coordinator owns): IO threads
    /// stamp accepted/parsed/reply_flushed and retire timelines.
    obs: Arc<ObsHub>,
}

impl Shared {
    fn lane_of(&self, conn: u64) -> &Lane {
        &self.lanes[((conn >> LANE_SHIFT) as usize) % self.lanes.len()]
    }

    fn push_done(&self, conn: u64, line: String, infer: bool, span: Option<Span>) {
        let lane = self.lane_of(conn);
        lane.done.lock().unwrap().push(Done {
            conn,
            line,
            infer,
            span,
        });
        lane.wake.signal();
    }

    pub(super) fn snapshot(&self) -> ConnPlaneSnapshot {
        self.stats.snapshot(
            "event",
            self.wire.as_str(),
            self.io_threads,
            self.pool.stats(),
        )
    }
}

impl CompletionSink for Shared {
    /// Runs on the completing thread (a runtime worker, or whoever
    /// drops an undelivered request): serialize there, so the IO loop
    /// only ever copies finished bytes.
    fn complete(&self, token: CompletionToken, resp: crate::coordinator::Response) {
        let mut resp = resp;
        resp.id = token.request; // echo the client-assigned id
        let span = resp.span;
        self.push_done(token.conn, self.fmt.response_line(&resp), true, span);
    }
}

/// Running event plane: acceptor + IO threads, stopped via [`stop`].
pub struct Reactor {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Reactor {
    /// Take ownership of an already-bound non-blocking listener and
    /// start serving on `cfg.io_threads` IO lanes.
    pub fn start(
        coord: Arc<Coordinator>,
        listener: TcpListener,
        cfg: &ServerConfig,
    ) -> Result<Reactor> {
        let io_threads = cfg.io_threads.max(1);
        let mut lanes = Vec::with_capacity(io_threads);
        for _ in 0..io_threads {
            lanes.push(Lane {
                inbox: Mutex::new(Vec::new()),
                done: Mutex::new(Vec::new()),
                wake: EventFd::new().context("creating lane eventfd")?,
            });
        }
        let shared = Arc::new(Shared {
            stop: std::sync::atomic::AtomicBool::new(false),
            stats: ConnStats::default(),
            // Two buffers per connection; retain enough for a busy
            // churn cycle without pinning 10k conns' worth of memory.
            pool: BufPool::new(256, 4096),
            lanes,
            accept_wake: EventFd::new().context("creating accept eventfd")?,
            io_threads,
            max_connections: cfg.max_connections,
            max_line_bytes: cfg.max_line_bytes,
            max_frame_bytes: cfg.max_frame_bytes,
            wire: cfg.wire_parser,
            fmt: protocol::ReplyFmt::new(cfg.compat_error_alias),
            idle_timeout: match cfg.idle_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            obs: coord.obs().clone(),
        });

        let mut threads = Vec::with_capacity(io_threads + 1);
        for idx in 0..io_threads {
            let shared = shared.clone();
            let coord = coord.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("zuluko-io-{idx}"))
                    .spawn(move || io_loop(idx, shared, coord))
                    .context("spawning io thread")?,
            );
        }
        let shared2 = shared.clone();
        threads.push(
            std::thread::Builder::new()
                .name("zuluko-accept".into())
                .spawn(move || accept_loop(shared2, listener))
                .context("spawning accept thread")?,
        );
        Ok(Reactor { shared, threads })
    }

    pub fn snapshot(&self) -> ConnPlaneSnapshot {
        self.shared.snapshot()
    }

    pub fn stop(self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.accept_wake.signal();
        for lane in &self.shared.lanes {
            lane.wake.signal();
        }
        for t in self.threads {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Acceptor
// ---------------------------------------------------------------------------

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    const T_LISTENER: u64 = 0;
    const T_STOP: u64 = 1;
    let epoll = match Epoll::new() {
        Ok(e) => e,
        Err(e) => {
            crate::error!("server", "acceptor epoll: {e}");
            return;
        }
    };
    if epoll.add(listener.as_raw_fd(), EPOLLIN, T_LISTENER).is_err()
        || epoll.add(shared.accept_wake.raw(), EPOLLIN, T_STOP).is_err()
    {
        crate::error!("server", "acceptor epoll registration failed");
        return;
    }
    let mut backoff = AcceptBackoff::new();
    let mut next_lane = 0usize;
    let mut serial = 0u64;
    let mut events = [EpollEvent::zeroed(); 8];
    while !shared.stop.load(Ordering::Acquire) {
        if epoll.wait(&mut events, 500).is_err() {
            std::thread::sleep(Duration::from_millis(10));
            continue;
        }
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        // Drain the accept queue; on error back off but NEVER exit —
        // a listener that stops accepting is a silently half-dead
        // server (the pre-reactor loop `break`ed here on EMFILE).
        loop {
            match sys::accept_nonblocking(listener.as_raw_fd()) {
                Ok(Some(stream)) => {
                    backoff.reset();
                    admit(&shared, stream, &mut next_lane, &mut serial);
                }
                Ok(None) => break,
                Err(e) => {
                    let delay = backoff.next_delay();
                    if AcceptBackoff::transient(&e) {
                        crate::warn!(
                            "server",
                            "accept: {e} — backing off {delay:?}"
                        );
                    } else {
                        crate::error!(
                            "server",
                            "accept: unexpected {e} — backing off {delay:?} and retrying"
                        );
                    }
                    std::thread::sleep(delay);
                    break;
                }
            }
        }
    }
}

fn admit(
    shared: &Arc<Shared>,
    mut stream: TcpStream,
    next_lane: &mut usize,
    serial: &mut u64,
) {
    if shared.stats.connections.load(Ordering::Relaxed) >= shared.max_connections {
        shared
            .stats
            .rejected_at_capacity
            .fetch_add(1, Ordering::Relaxed);
        // Rate-limited: a connection storm hits this once per accept.
        if let Some(sup) = CAPACITY_LOG.allow() {
            crate::warn!(
                "server",
                "rejecting connection: at cap ({}){}",
                shared.max_connections,
                suppressed_note(sup)
            );
        }
        // Structured reject so a load generator can tell shed-at-socket
        // from network failure.  Best effort: the socket is fresh and
        // non-blocking, so one short write almost always fits.
        let mut line = shared
            .fmt
            .error_line_kind(0, "at_capacity", "connection limit reached")
            .into_bytes();
        line.push(b'\n');
        let _ = stream.write_all(&line);
        return; // drop closes
    }
    shared.stats.connections.fetch_add(1, Ordering::Relaxed);
    shared.stats.accepted.fetch_add(1, Ordering::Relaxed);
    *serial += 1;
    let token =
        ((*next_lane as u64) << LANE_SHIFT) | (*serial & ((1u64 << LANE_SHIFT) - 1));
    let lane = &shared.lanes[*next_lane];
    lane.inbox.lock().unwrap().push((token, stream));
    lane.wake.signal();
    *next_lane = (*next_lane + 1) % shared.lanes.len();
}

// ---------------------------------------------------------------------------
// IO threads
// ---------------------------------------------------------------------------

/// Per-connection state, owned exclusively by one IO thread.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: WriteBuf,
    /// Requests submitted on this connection whose reply line has not
    /// yet been queued (inference in workers + commands in flight).
    pending: usize,
    last_activity: Instant,
    /// Currently-registered epoll interest mask.
    interest: u32,
    read_paused: bool,
    /// Half-closed or errored: flush what's owed, then close.
    closing: bool,
    /// Line mode ⇄ expecting-payload-bytes mode (binary frame lane).
    framing: Framing,
    /// `binary_frames` negotiated via `{"cmd":"hello"}`; sticky for
    /// the connection's lifetime.  Never set = plain JSON, unchanged.
    binary_frames: bool,
    /// What to do with the payload the framing layer is collecting.
    pending_frame: Option<PendingFrame>,
}

/// Disposition of an in-flight frame payload, decided when its header
/// line was processed.
enum PendingFrame {
    /// The header was rejected (reply already queued) but declared a
    /// trustworthy `len`: consume that many bytes and keep serving.
    Skip,
    /// Valid header on a negotiated connection: decode the payload into
    /// the addressed model's arena and submit.
    Submit {
        id: u64,
        header: FrameHeader,
        slo: Slo,
        model: Option<String>,
        span: Span,
    },
}

fn io_loop(idx: usize, shared: Arc<Shared>, coord: Arc<Coordinator>) {
    let epoll = match Epoll::new() {
        Ok(e) => e,
        Err(e) => {
            crate::error!("server", "io-{idx} epoll: {e}");
            return;
        }
    };
    let lane = &shared.lanes[idx];
    if epoll.add(lane.wake.raw(), EPOLLIN, TOKEN_WAKE).is_err() {
        crate::error!("server", "io-{idx} wake registration failed");
        return;
    }
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut events = vec![EpollEvent::zeroed(); 512];
    // One scan tape per IO lane, reused across every request the lane
    // parses — steady-state parsing allocates nothing.
    let mut tape = WireTape::new();
    let mut last_sweep = Instant::now();
    let timeout_ms = match shared.idle_timeout {
        Some(d) => ((d.as_millis() / 4) as i32).clamp(10, 500),
        None => 500,
    };
    loop {
        let n = match epoll.wait(&mut events, timeout_ms) {
            Ok(n) => n,
            Err(e) => {
                crate::error!("server", "io-{idx} epoll_wait: {e}");
                break;
            }
        };
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        for ev in &events[..n] {
            let (mask, token) = ev.parts();
            if token == TOKEN_WAKE {
                lane.wake.drain();
                let fresh: Vec<_> = lane.inbox.lock().unwrap().drain(..).collect();
                for (tok, stream) in fresh {
                    register_conn(&epoll, &shared, &mut conns, tok, stream);
                }
                let done: Vec<Done> = lane.done.lock().unwrap().drain(..).collect();
                for d in done {
                    deliver(&epoll, &shared, &mut conns, d);
                }
            } else {
                handle_event(
                    &epoll, &shared, &coord, &mut conns, token, mask, &mut tape,
                );
            }
        }
        if let Some(idle) = shared.idle_timeout {
            if last_sweep.elapsed() >= Duration::from_millis(timeout_ms as u64) {
                sweep_idle(&epoll, &shared, &mut conns, idle);
                last_sweep = Instant::now();
            }
        }
    }
    // Teardown: close everything this lane owns.
    let tokens: Vec<u64> = conns.keys().copied().collect();
    for t in tokens {
        close_conn(&epoll, &shared, &mut conns, t);
    }
}

fn register_conn(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    stream: TcpStream,
) {
    stream.set_nodelay(true).ok();
    let interest = EPOLLIN | EPOLLRDHUP;
    if let Err(e) = epoll.add(stream.as_raw_fd(), interest, token) {
        crate::warn!("server", "register conn: {e}");
        shared.stats.connections.fetch_sub(1, Ordering::Relaxed);
        return;
    }
    conns.insert(
        token,
        Conn {
            stream,
            rbuf: shared.pool.take(),
            wbuf: WriteBuf::new(shared.pool.take(), WBUF_HIGH),
            pending: 0,
            last_activity: Instant::now(),
            interest,
            read_paused: false,
            closing: false,
            framing: Framing::new(),
            binary_frames: false,
            pending_frame: None,
        },
    );
}

fn close_conn(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
) {
    if let Some(mut c) = conns.remove(&token) {
        let _ = epoll.del(c.stream.as_raw_fd());
        // Discard unread input (bounded — the socket is non-blocking):
        // closing with bytes still queued makes the kernel send RST,
        // which can destroy reply lines (the oversize reject, a final
        // response) still sitting in the client's receive queue.
        let mut scratch = [0u8; 4096];
        for _ in 0..64 {
            match c.stream.read(&mut scratch) {
                Ok(n) if n > 0 => continue,
                _ => break,
            }
        }
        shared.pool.put(c.rbuf);
        shared.pool.put(c.wbuf.into_buf());
        shared.stats.connections.fetch_sub(1, Ordering::Relaxed);
        // In-flight replies addressed here are dropped on delivery;
        // the ReplySink already fired, so nothing leaks.
    }
}

/// Flush, reconcile epoll interest with buffer/pause state, and close
/// if this connection is done.  The one place interest transitions
/// happen, so the invariants stay in a single spot.
fn settle(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
) {
    let close_now = match conns.get_mut(&token) {
        None => return,
        Some(c) => {
            if !c.wbuf.is_empty() && c.wbuf.flush(&mut c.stream).is_err() {
                true
            } else {
                // Backpressure transitions (count each pause once).
                if !c.read_paused && c.wbuf.over_high() {
                    c.read_paused = true;
                    shared
                        .stats
                        .backpressure_events
                        .fetch_add(1, Ordering::Relaxed);
                } else if c.read_paused && c.wbuf.under_low() {
                    c.read_paused = false;
                }
                if c.closing && c.wbuf.is_empty() && c.pending == 0 {
                    true
                } else {
                    let mut want = 0u32;
                    if !c.read_paused && !c.closing {
                        want |= EPOLLIN | EPOLLRDHUP;
                    }
                    if !c.wbuf.is_empty() {
                        want |= EPOLLOUT;
                    }
                    if want != c.interest
                        && epoll.modify(c.stream.as_raw_fd(), want, token).is_ok()
                    {
                        c.interest = want;
                    }
                    false
                }
            }
        }
    };
    if close_now {
        close_conn(epoll, shared, conns, token);
    }
}

fn deliver(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    conns: &mut HashMap<u64, Conn>,
    d: Done,
) {
    if d.infer {
        shared.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
        shared.stats.completions.fetch_add(1, Ordering::Relaxed);
    }
    // Retire the timeline on the owning IO thread (its lane's ring) —
    // even if the connection vanished, the request did complete.
    if let Some(mut s) = d.span {
        s.set(Stage::ReplyFlushed, shared.obs.now_ns());
        let lane = ((d.conn >> LANE_SHIFT) as usize) % shared.lanes.len();
        shared.obs.complete(&mut s, lane);
    }
    let Some(c) = conns.get_mut(&d.conn) else {
        return; // connection closed while the request was in flight
    };
    c.pending = c.pending.saturating_sub(1);
    c.last_activity = Instant::now();
    c.wbuf.push_line(&d.line);
    settle(epoll, shared, conns, d.conn);
}

#[allow(clippy::too_many_arguments)]
fn handle_event(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    coord: &Arc<Coordinator>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    mask: u32,
    tape: &mut WireTape,
) {
    if !conns.contains_key(&token) {
        return; // raced with a close earlier in this batch
    }
    if mask & (EPOLLERR | EPOLLHUP) != 0 {
        close_conn(epoll, shared, conns, token);
        return;
    }
    if mask & (EPOLLIN | EPOLLRDHUP) != 0 {
        if !on_readable(shared, coord, conns, token, tape) {
            close_conn(epoll, shared, conns, token);
            return;
        }
    }
    settle(epoll, shared, conns, token);
}

/// Read and process everything currently available.  Returns false if
/// the connection must be closed immediately (IO error).
fn on_readable(
    shared: &Arc<Shared>,
    coord: &Arc<Coordinator>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    tape: &mut WireTape,
) -> bool {
    let c = match conns.get_mut(&token) {
        Some(c) => c,
        None => return true,
    };
    if c.read_paused || c.closing {
        return true;
    }
    let mut chunk = [0u8; 16 * 1024];
    let mut got_bytes = false;
    for _ in 0..READ_CHUNKS_PER_EVENT {
        match c.stream.read(&mut chunk) {
            Ok(0) => {
                // Client finished sending (EOF/half-close): answer what
                // is owed, then close.
                c.closing = true;
                break;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&chunk[..n]);
                got_bytes = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if got_bytes {
        c.last_activity = Instant::now();
    }
    // Move the read buffer out so each complete line can be parsed *in
    // place* (a borrowed span, no per-line String) while `conns` stays
    // mutable for dispatch.  The connection keeps an empty placeholder
    // until the buffer is restored below.
    let mut rbuf = std::mem::take(&mut c.rbuf);
    let mut start = 0usize;
    loop {
        let item = match conns.get_mut(&token) {
            Some(c) => c.framing.next_item(&rbuf, start, shared.max_line_bytes),
            None => return true,
        };
        match item {
            Ok(Some(WireItem::Line(span))) => {
                let end = span.end;
                let line = rbuf.get(span).unwrap_or(&[]);
                let was_closing = conns.get(&token).is_some_and(|c| c.closing);
                process_line(shared, coord, conns, token, line, tape);
                start = end + 1;
                if !conns.contains_key(&token) {
                    // Closed mid-batch: close_conn already returned the
                    // placeholder to the pool (counters are balanced),
                    // so the real buffer is simply dropped.
                    return true;
                }
                if !was_closing && conns.get(&token).is_some_and(|c| c.closing) {
                    // This line set closing: a non-resyncable frame
                    // reject.  The reply is queued; the rest of the
                    // input is untrustworthy and discarded with the
                    // buffer (like oversize).  EOF-driven closing (set
                    // before the loop) keeps draining buffered lines —
                    // answer what is owed, then close.
                    return true;
                }
            }
            Ok(Some(WireItem::Frame(range))) => {
                let end = range.end;
                let payload = rbuf.get(range).unwrap_or(&[]);
                process_frame(shared, coord, conns, token, payload);
                start = end;
                if !conns.contains_key(&token) {
                    return true;
                }
            }
            Ok(None) => break,
            Err(over) => {
                shared
                    .stats
                    .oversize_rejected
                    .fetch_add(1, Ordering::Relaxed);
                if let Some(c) = conns.get_mut(&token) {
                    c.wbuf.push_line(&shared.fmt.error_line_kind(
                        0,
                        "bad_request",
                        &format!(
                            "request line exceeds {} bytes (got {}+)",
                            shared.max_line_bytes, over.seen
                        ),
                    ));
                    c.closing = true;
                }
                // Closing: discard the buffered input with the buffer.
                return true;
            }
        }
    }
    rbuf.drain(..start);
    if let Some(c) = conns.get_mut(&token) {
        c.rbuf = rbuf;
    }
    true
}

/// Dispatch one request line.  Commands answer inline; inference and
/// reload go async — the reply line arrives through the lane's
/// completion queue, which is what lets one connection keep many
/// requests in flight (pipelining).
fn process_line(
    shared: &Arc<Shared>,
    coord: &Arc<Coordinator>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    line: &[u8],
    tape: &mut WireTape,
) {
    if wire::is_blank(line) {
        return;
    }
    // Trace epoch: the line is fully framed — "accepted" in timeline
    // terms.  Only inference requests carry the span further.
    let t_accepted = shared.obs.now_ns();
    let parsed = protocol::parse_line(shared.wire, line, tape);
    let c = match conns.get_mut(&token) {
        Some(c) => c,
        None => return,
    };
    match parsed {
        Err(e) => c.wbuf.push_line(&shared.fmt.error_line_kind(
            0,
            "bad_request",
            &format!("bad request: {e}"),
        )),
        Ok((ClientMsg::Ping, _)) => c.wbuf.push_line("{\"ok\":true,\"pong\":true}"),
        Ok((ClientMsg::Hello { binary_frames }, _)) => {
            // Opt-in is sticky for the connection's lifetime; repeating
            // the handshake is idempotent (no double-count, no downgrade).
            if binary_frames && !c.binary_frames {
                c.binary_frames = true;
                shared.stats.frames_negotiated.fetch_add(1, Ordering::Relaxed);
            }
            c.wbuf.push_line(&protocol::hello_line(
                "event",
                shared.wire.as_str(),
                c.binary_frames,
            ));
        }
        Ok((ClientMsg::Stats, _)) => {
            let line =
                protocol::stats_line_with(&coord.stats(), &shared.snapshot());
            c.wbuf.push_line(&line);
        }
        Ok((ClientMsg::Metrics, _)) => {
            let line = protocol::metrics_line(&coord.metrics(), &shared.snapshot());
            c.wbuf.push_line(&line);
        }
        Ok((ClientMsg::Trace { n }, _)) => {
            let hub = coord.obs();
            c.wbuf
                .push_line(&protocol::trace_line(&hub.traces(n), &hub.slow_log(n)));
        }
        Ok((ClientMsg::Policy, _)) => {
            c.wbuf.push_line(&protocol::policy_line(&coord.policy_snapshot()))
        }
        Ok((ClientMsg::Models, _)) => c.wbuf.push_line(&protocol::models_line(
            coord.default_model(),
            &coord.stats().models,
        )),
        Ok((ClientMsg::Reload { model }, _)) => {
            // Reload compiles engines — far too slow for the IO loop.
            // Run it on its own thread and route the result through the
            // completion queue like any other async reply.
            c.pending += 1;
            let coord = coord.clone();
            let shared = shared.clone();
            std::thread::spawn(move || {
                let line = match coord.reload(model.as_deref()) {
                    Ok(report) => protocol::reload_line(&report),
                    Err(e) => shared.fmt.error_line_kind(
                        0,
                        "reload_failed",
                        &format!("{e:#}"),
                    ),
                };
                shared.push_done(token, line, false, None);
            });
        }
        Ok((
            ClientMsg::Infer {
                id,
                image,
                slo,
                model,
            },
            wire_key,
        )) => match image {
            ImageSpec::Frame(header) => {
                let reject: Option<(&str, String)> = if !c.binary_frames {
                    Some((
                        "unsupported_feature",
                        "binary_frames not negotiated; send \
                         {\"cmd\":\"hello\",\"features\":{\"binary_frames\":true}} \
                         first"
                            .to_string(),
                    ))
                } else {
                    header
                        .check(shared.max_frame_bytes)
                        .err()
                        .map(|msg| ("bad_frame", msg))
                };
                match reject {
                    Some((kind, msg)) => {
                        shared.stats.frames_rejected.fetch_add(1, Ordering::Relaxed);
                        c.wbuf.push_line(&shared.fmt.error_line_kind(id, kind, &msg));
                        if header.resyncable(shared.max_frame_bytes) {
                            // The declared len is trustworthy even though
                            // the header is not: consume exactly that many
                            // payload bytes and keep the connection alive.
                            c.framing.expect_payload(header.len);
                            c.pending_frame = Some(PendingFrame::Skip);
                        } else {
                            // Can't tell where the payload ends — the only
                            // safe resync point is a fresh connection.
                            c.closing = true;
                        }
                    }
                    None => {
                        let mut span = shared.obs.begin_at(t_accepted);
                        span.set(Stage::Parsed, shared.obs.now_ns());
                        c.framing.expect_payload(header.len);
                        c.pending_frame = Some(PendingFrame::Submit {
                            id,
                            header,
                            slo,
                            model,
                            span,
                        });
                    }
                }
            }
            image => {
                let mut span = shared.obs.begin_at(t_accepted);
                span.set(Stage::Parsed, shared.obs.now_ns());
                match submit_infer(
                    shared,
                    coord,
                    token,
                    id,
                    model.as_deref(),
                    PixelSource::Spec(&image),
                    wire_key,
                    slo,
                    span,
                ) {
                    Some(reply) => c.wbuf.push_line(&reply),
                    None => {
                        c.pending += 1;
                        shared.stats.in_flight.fetch_add(1, Ordering::Relaxed);
                        shared
                            .stats
                            .peak_conn_in_flight
                            .fetch_max(c.pending, Ordering::Relaxed);
                    }
                }
            }
        },
    }
}

/// Consume one complete frame payload (borrowed from the read buffer)
/// according to the disposition recorded when its header line arrived.
fn process_frame(
    shared: &Arc<Shared>,
    coord: &Arc<Coordinator>,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    payload: &[u8],
) {
    let c = match conns.get_mut(&token) {
        Some(c) => c,
        None => return,
    };
    match c.pending_frame.take() {
        None => {
            // Framing only enters payload mode through expect_payload,
            // which is always paired with a disposition.
            debug_assert!(false, "frame payload with no pending disposition");
        }
        Some(PendingFrame::Skip) => {} // reject reply already queued
        Some(PendingFrame::Submit {
            id,
            header,
            slo,
            model,
            span,
        }) => {
            shared.stats.frames_received.fetch_add(1, Ordering::Relaxed);
            shared
                .stats
                .frame_bytes
                .fetch_add(payload.len() as u64, Ordering::Relaxed);
            match submit_infer(
                shared,
                coord,
                token,
                id,
                model.as_deref(),
                PixelSource::Frame(&header, payload),
                None,
                slo,
                span,
            ) {
                Some(reply) => c.wbuf.push_line(&reply),
                None => {
                    c.pending += 1;
                    shared.stats.in_flight.fetch_add(1, Ordering::Relaxed);
                    shared
                        .stats
                        .peak_conn_in_flight
                        .fetch_max(c.pending, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Async twin of the threads-plane `infer_reply`: resolve, consult the
/// wire-key cache, decode into the model's arena, submit with a
/// completion sink.  `Some(line)` is an immediate reply (cache hit or
/// structured reject — the sink was disarmed); `None` means the request
/// is in flight and exactly one completion will follow.
#[allow(clippy::too_many_arguments)]
fn submit_infer(
    shared: &Arc<Shared>,
    coord: &Coordinator,
    conn: u64,
    id: u64,
    model: Option<&str>,
    src: PixelSource<'_>,
    wire_key: Option<u64>,
    slo: Slo,
    span: Span,
) -> Option<String> {
    const ATTEMPTS: usize = 2;
    let mut decoded: Option<crate::tensor::PooledTensor> = None;
    for attempt in 0..ATTEMPTS {
        let lease = match coord.lease(model) {
            Ok(l) => l,
            Err(e @ SubmitError::UnknownModel(_)) => {
                return Some(shared.fmt.error_line_kind(
                    id,
                    "unknown_model",
                    &e.to_string(),
                ))
            }
            Err(e @ SubmitError::ModelUnavailable { .. }) => {
                return Some(shared.fmt.error_line_kind(
                    id,
                    "model_unavailable",
                    &e.to_string(),
                ))
            }
            Err(e) => return Some(shared.fmt.error_line(id, &e.to_string())),
        };
        if let Some(mut resp) = wire_key.and_then(|k| lease.cached_response(k)) {
            resp.id = id;
            // Wire-key hit: the reply is queued right here on the IO
            // thread — stamp and retire the timeline immediately.
            let mut s = span;
            s.id = id;
            s.flags |= flag::CACHE_HIT;
            s.set(Stage::ReplyFlushed, shared.obs.now_ns());
            let lane = ((conn >> LANE_SHIFT) as usize) % shared.lanes.len();
            shared.obs.complete(&mut s, lane);
            return Some(shared.fmt.response_line(&resp));
        }
        let hw = lease.input_hw();
        let tensor = match decoded.take().filter(|t| t.shape() == [hw, hw, 3]) {
            Some(t) => t,
            None => match super::load_pixels(&src, hw, &lease.arena()) {
                Err(e) => {
                    return Some(shared.fmt.error_line(id, &format!("image: {e}")))
                }
                Ok(t) => t,
            },
        };
        let sink = ReplySink::completion(
            shared.clone() as Arc<dyn CompletionSink>,
            CompletionToken { conn, request: id },
        );
        // Span is Copy: a Closed retry re-submits the same timeline.
        return match coord.submit_on_sink_traced(&lease, tensor, slo, wire_key, sink, span)
        {
            Ok(()) => None,
            // Retired mid-swap: resubmit the already-decoded pixels to
            // the fresh generation (the disarmed sink delivered
            // nothing, so a fresh sink on attempt 2 is exactly-once).
            Err((SubmitError::Closed, img)) if attempt + 1 < ATTEMPTS => {
                decoded = img;
                continue;
            }
            Err((SubmitError::Overloaded, _)) => {
                Some(shared.fmt.error_line_kind(id, "overloaded", "overloaded"))
            }
            Err((
                SubmitError::Shed {
                    predicted_ms,
                    deadline_ms,
                },
                _,
            )) => Some(shared.fmt.shed_line(id, predicted_ms, deadline_ms)),
            Err((e, _)) => Some(shared.fmt.error_line(id, &e.to_string())),
        };
    }
    Some(shared.fmt.error_line(id, "closed"))
}

fn sweep_idle(
    epoll: &Epoll,
    shared: &Arc<Shared>,
    conns: &mut HashMap<u64, Conn>,
    idle: Duration,
) {
    let evict: Vec<u64> = conns
        .iter()
        .filter(|(_, c)| {
            c.pending == 0 && c.wbuf.is_empty() && c.last_activity.elapsed() >= idle
        })
        .map(|(t, _)| *t)
        .collect();
    for token in evict {
        shared.stats.idle_evicted.fetch_add(1, Ordering::Relaxed);
        close_conn(epoll, shared, conns, token);
    }
}
