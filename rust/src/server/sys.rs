//! Raw Linux syscall surface for the event-driven connection plane.
//!
//! The offline/vendored build rules out the `libc`/`mio` crates, so the
//! handful of readiness primitives the reactor needs — `epoll`,
//! `eventfd`, `accept4`, `setrlimit` — are declared here directly
//! against the C runtime std already links.  Everything is wrapped in
//! small RAII types ([`Epoll`], [`EventFd`]) so the reactor itself
//! contains no `unsafe`.
//!
//! Linux-only by design: the paper's target (and CI) is a Linux
//! embedded board; there is no portability layer to maintain.

use std::io;
use std::net::TcpStream;
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::{FromRawFd, RawFd};

// -- epoll event masks (uapi/linux/eventpoll.h) -----------------------------
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000;

const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

const SOCK_NONBLOCK: c_int = 0o4000;
const SOCK_CLOEXEC: c_int = 0o2000000;

const RLIMIT_NOFILE: c_int = 7;

/// Kernel epoll event record.  x86-64 packs it to match the 32-bit
/// layout (the one ABI quirk of epoll); every other arch uses natural
/// alignment.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }

    /// Copy out of a possibly-packed struct (direct field reads of a
    /// packed struct are UB-adjacent on references; go through a copy).
    pub fn parts(&self) -> (u32, u64) {
        let e = *self;
        (e.events, e.data)
    }
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout_ms: c_int,
    ) -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn accept4(
        sockfd: c_int,
        addr: *mut c_void,
        addrlen: *mut u32,
        flags: c_int,
    ) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance (closed on drop).
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with interest `events`; readiness reports carry
    /// `token` back in [`EpollEvent::data`].
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Replace `fd`'s interest set (token may change too).
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        // The event argument is ignored for DEL on modern kernels but
        // must be non-null on pre-2.6.9 ones; pass one unconditionally.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, filling `events`.  Returns the number of
    /// ready entries; a signal interruption reads as zero events.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        let n = unsafe {
            epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// A non-blocking eventfd: the reactor's cross-thread doorbell.
/// `signal()` from any thread makes the owning epoll loop's `wait`
/// return; the loop then `drain()`s it back to zero.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Ring the doorbell (best effort: a full counter already wakes).
    pub fn signal(&self) {
        let one: u64 = 1;
        unsafe { write(self.fd, (&one as *const u64).cast(), 8) };
    }

    /// Reset the counter so the next `signal` re-arms readiness.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe { read(self.fd, (&mut buf as *mut u64).cast(), 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

// Doorbell fds cross threads by design; they carry no thread-local state.
unsafe impl Send for EventFd {}
unsafe impl Sync for EventFd {}

/// One non-blocking `accept4` on a listening socket.
/// `Ok(Some)` hands back an already-non-blocking stream, `Ok(None)`
/// means no pending connection (EAGAIN), `Err` is a real accept error
/// for the caller's backoff policy.
pub fn accept_nonblocking(listener_fd: RawFd) -> io::Result<Option<TcpStream>> {
    let fd = unsafe {
        accept4(
            listener_fd,
            std::ptr::null_mut(),
            std::ptr::null_mut(),
            SOCK_NONBLOCK | SOCK_CLOEXEC,
        )
    };
    if fd >= 0 {
        return Ok(Some(unsafe { TcpStream::from_raw_fd(fd) }));
    }
    let e = io::Error::last_os_error();
    if e.kind() == io::ErrorKind::WouldBlock {
        return Ok(None);
    }
    Err(e)
}

/// Raise the process fd soft limit toward `want` (clamped at the hard
/// limit).  Returns the effective soft limit.  Needed by the E13
/// stress driver: thousands of sockets blow through the default 1024.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = Rlimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.cur >= want {
        return Ok(lim.cur);
    }
    let new = Rlimit {
        cur: want.min(lim.max),
        max: lim.max,
    };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(new.cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_signal_wakes_epoll_and_drain_rearms() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), EPOLLIN, 42).unwrap();

        let mut out = [EpollEvent::zeroed(); 4];
        // Nothing signalled: zero-timeout wait sees nothing.
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 0);

        ev.signal();
        let n = ep.wait(&mut out, 1000).unwrap();
        assert_eq!(n, 1);
        let (events, token) = out[0].parts();
        assert_eq!(token, 42);
        assert!(events & EPOLLIN != 0);

        // Level-triggered: still readable until drained.
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 1);
        ev.drain();
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 0);
    }

    #[test]
    fn epoll_tracks_interest_modification() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), EPOLLIN, 1).unwrap();
        ev.signal();
        let mut out = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 1);
        // Drop read interest: readiness stops being reported.
        ep.modify(ev.raw(), 0, 1).unwrap();
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 0);
        // Restore it: the level-triggered readable state comes back.
        ep.modify(ev.raw(), EPOLLIN, 7).unwrap();
        let n = ep.wait(&mut out, 0).unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].parts().1, 7);
        ep.del(ev.raw()).unwrap();
        assert_eq!(ep.wait(&mut out, 0).unwrap(), 0);
    }

    #[test]
    fn nofile_limit_is_queryable_and_monotone() {
        let cur = raise_nofile_limit(0).unwrap();
        assert!(cur > 0);
        // Raising toward the current value is a no-op, never an error.
        assert!(raise_nofile_limit(cur).unwrap() >= cur);
    }
}
