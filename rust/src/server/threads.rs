//! Thread-per-connection fallback plane (`--conn-plane threads`).
//!
//! Kept as the E13 ablation baseline: identical protocol and
//! coordinator path as the event plane, but one OS thread per
//! connection and a blocking `recv()` per request — the architecture
//! the reactor replaced.  The satellite fixes land here too (accept
//! backoff instead of a fatal break, bounded request lines, structured
//! `at_capacity` rejects), so the ablation measures the *connection
//! plane* and not unrelated bug fixes.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::config::{ServerConfig, WireParser};
use crate::coordinator::{Coordinator, SubmitError};
use crate::obs::{flag, Span, Stage};
use crate::policy::Slo;
use crate::tensor::PooledTensor;
use crate::util::log::{suppressed_note, CAPACITY_LOG};
use crate::util::wire::{self, WireTape};

use super::conn::AcceptBackoff;
use super::protocol::{self, ClientMsg, ImageSpec};
use super::{ConnPlaneSnapshot, ConnStats, PixelSource};

/// Running thread-per-connection plane.
pub struct ThreadsPlane {
    stats: Arc<ConnStats>,
    stop: Arc<AtomicBool>,
    wire: WireParser,
    accept_thread: std::thread::JoinHandle<()>,
}

impl ThreadsPlane {
    pub fn start(
        coord: Arc<Coordinator>,
        listener: TcpListener,
        cfg: &ServerConfig,
    ) -> Result<ThreadsPlane> {
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ConnStats::default());
        let max_connections = cfg.max_connections;
        let max_line_bytes = cfg.max_line_bytes;
        let max_frame_bytes = cfg.max_frame_bytes;
        let wire = cfg.wire_parser;
        let fmt = protocol::ReplyFmt::new(cfg.compat_error_alias);
        let (stop2, stats2) = (stop.clone(), stats.clone());

        let accept_thread = std::thread::Builder::new()
            .name("zuluko-accept".into())
            .spawn(move || {
                let mut backoff = AcceptBackoff::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, peer)) => {
                            backoff.reset();
                            if stats2.connections.load(Ordering::Relaxed)
                                >= max_connections
                            {
                                stats2
                                    .rejected_at_capacity
                                    .fetch_add(1, Ordering::Relaxed);
                                // Rate-limited: under a connection storm
                                // this fires per accept (DESIGN.md §10).
                                if let Some(sup) = CAPACITY_LOG.allow() {
                                    crate::warn!(
                                        "server",
                                        "rejecting {peer}: at connection cap{}",
                                        suppressed_note(sup)
                                    );
                                }
                                // Structured reject, not a silent drop.
                                let mut line = fmt
                                    .error_line_kind(
                                        0,
                                        "at_capacity",
                                        "connection limit reached",
                                    )
                                    .into_bytes();
                                line.push(b'\n');
                                let _ = stream.write_all(&line);
                                continue;
                            }
                            stats2.connections.fetch_add(1, Ordering::Relaxed);
                            stats2.accepted.fetch_add(1, Ordering::Relaxed);
                            let coord = coord.clone();
                            let stats3 = stats2.clone();
                            std::thread::spawn(move || {
                                // Drop guard so the slot is released even
                                // if the handler panics mid-connection.
                                struct Slot(Arc<ConnStats>);
                                impl Drop for Slot {
                                    fn drop(&mut self) {
                                        self.0
                                            .connections
                                            .fetch_sub(1, Ordering::Relaxed);
                                    }
                                }
                                let _slot = Slot(stats3.clone());
                                let _ = handle_conn(
                                    stream,
                                    &coord,
                                    &stats3,
                                    max_line_bytes,
                                    max_frame_bytes,
                                    wire,
                                    fmt,
                                );
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            // Transient fd pressure (EMFILE & friends) or
                            // anything else: log, back off, keep accepting.
                            // The old loop `break`ed here, permanently
                            // killing the listener.
                            let delay = backoff.next_delay();
                            if AcceptBackoff::transient(&e) {
                                crate::warn!(
                                    "server",
                                    "accept: {e} — backing off {delay:?}"
                                );
                            } else {
                                crate::error!(
                                    "server",
                                    "accept: unexpected {e} — backing off {delay:?} and retrying"
                                );
                            }
                            std::thread::sleep(delay);
                        }
                    }
                }
            })
            .context("spawning accept thread")?;

        Ok(ThreadsPlane {
            stats,
            stop,
            wire,
            accept_thread,
        })
    }

    pub fn snapshot(&self) -> ConnPlaneSnapshot {
        self.stats.snapshot(
            "threads",
            self.wire.as_str(),
            0,
            super::conn::BufPoolStats::default(),
        )
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.accept_thread.join();
    }
}

enum LineRead {
    Line,
    Eof,
    Oversize,
}

/// `read_line` with a byte budget: a client streaming bytes without a
/// newline gets cut off at `max + 1` instead of growing the buffer
/// without bound (the OOM-DoS the unbounded version allowed).
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let n = reader
        .by_ref()
        .take(max as u64 + 1)
        .read_until(b'\n', buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if buf.last() != Some(&b'\n') && buf.len() > max {
        return Ok(LineRead::Oversize);
    }
    Ok(LineRead::Line)
}

/// Blocking `read_exact` of a frame payload.  The buffer is reused
/// across frames on this connection; a short read (client disconnected
/// mid-payload) surfaces as the `Err` that ends the handler.
fn read_payload(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    n: usize,
) -> std::io::Result<()> {
    buf.clear();
    buf.resize(n, 0);
    reader.read_exact(buf)
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    stats: &ConnStats,
    max_line_bytes: usize,
    max_frame_bytes: usize,
    wire_parser: WireParser,
    fmt: protocol::ReplyFmt,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut raw = Vec::new();
    // Frame payload staging, reused across frames on this connection.
    // (The event plane decodes payloads in place from its pooled read
    // buffer; this plane's BufReader has no such buffer to borrow.)
    let mut payload = Vec::new();
    // `binary_frames` negotiated via `{"cmd":"hello"}`; sticky for the
    // connection's lifetime.  Never set = plain JSON, unchanged.
    let mut negotiated = false;
    // Per-connection scan tape, reused for every request on this
    // thread — steady-state parsing allocates nothing.
    let mut tape = WireTape::new();
    loop {
        match read_bounded_line(&mut reader, &mut raw, max_line_bytes)? {
            LineRead::Eof => return Ok(()), // client closed
            LineRead::Oversize => {
                stats.oversize_rejected.fetch_add(1, Ordering::Relaxed);
                let reply = fmt.error_line_kind(
                    0,
                    "bad_request",
                    &format!("request line exceeds {max_line_bytes} bytes"),
                );
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                // Discard what the client already sent (briefly, bounded)
                // before closing: close-with-unread-data sends RST, which
                // can destroy the reject line still in the client's
                // receive queue.
                let _ = reader
                    .get_ref()
                    .set_read_timeout(Some(std::time::Duration::from_millis(100)));
                let mut scratch = [0u8; 4096];
                for _ in 0..256 {
                    match reader.read(&mut scratch) {
                        Ok(n) if n > 0 => continue,
                        _ => break,
                    }
                }
                return Ok(()); // close: the rest of the stream is garbage
            }
            LineRead::Line => {}
        }
        if wire::is_blank(&raw) {
            continue;
        }
        // Trace epoch: the request line is fully read — "accepted" in
        // timeline terms (DESIGN.md §10).  Only infer requests carry
        // the span further.
        let t_accepted = coord.obs().now_ns();
        let (reply, span) = match protocol::parse_line(wire_parser, &raw, &mut tape) {
            Err(e) => (
                fmt.error_line_kind(0, "bad_request", &format!("bad request: {e}")),
                None,
            ),
            Ok((ClientMsg::Ping, _)) => ("{\"ok\":true,\"pong\":true}".to_string(), None),
            Ok((ClientMsg::Hello { binary_frames }, _)) => {
                // Opt-in is sticky for the connection's lifetime;
                // repeating the handshake is idempotent.
                if binary_frames && !negotiated {
                    negotiated = true;
                    stats.frames_negotiated.fetch_add(1, Ordering::Relaxed);
                }
                (
                    protocol::hello_line("threads", wire_parser.as_str(), negotiated),
                    None,
                )
            }
            Ok((ClientMsg::Stats, _)) => (
                protocol::stats_line_with(
                    &coord.stats(),
                    &stats.snapshot(
                        "threads",
                        wire_parser.as_str(),
                        0,
                        super::conn::BufPoolStats::default(),
                    ),
                ),
                None,
            ),
            Ok((ClientMsg::Metrics, _)) => (
                protocol::metrics_line(
                    &coord.metrics(),
                    &stats.snapshot(
                        "threads",
                        wire_parser.as_str(),
                        0,
                        super::conn::BufPoolStats::default(),
                    ),
                ),
                None,
            ),
            Ok((ClientMsg::Trace { n }, _)) => {
                let hub = coord.obs();
                (protocol::trace_line(&hub.traces(n), &hub.slow_log(n)), None)
            }
            Ok((ClientMsg::Policy, _)) => {
                (protocol::policy_line(&coord.policy_snapshot()), None)
            }
            Ok((ClientMsg::Models, _)) => (
                protocol::models_line(coord.default_model(), &coord.stats().models),
                None,
            ),
            Ok((ClientMsg::Reload { model }, _)) => match coord.reload(model.as_deref()) {
                Ok(report) => (protocol::reload_line(&report), None),
                Err(e) => (
                    fmt.error_line_kind(0, "reload_failed", &format!("{e:#}")),
                    None,
                ),
            },
            Ok((
                ClientMsg::Infer {
                    id,
                    image,
                    slo,
                    model,
                },
                wire_key,
            )) => {
                let mut span = coord.obs().begin_at(t_accepted);
                span.set(Stage::Parsed, coord.obs().now_ns());
                match image {
                    ImageSpec::Frame(header) => {
                        let reject: Option<(&str, String)> = if !negotiated {
                            Some((
                                "unsupported_feature",
                                "binary_frames not negotiated; send \
                                 {\"cmd\":\"hello\",\"features\":{\"binary_frames\":true}} \
                                 first"
                                    .to_string(),
                            ))
                        } else {
                            header
                                .check(max_frame_bytes)
                                .err()
                                .map(|msg| ("bad_frame", msg))
                        };
                        match reject {
                            Some((kind, msg)) => {
                                stats.frames_rejected.fetch_add(1, Ordering::Relaxed);
                                let reply = fmt.error_line_kind(id, kind, &msg);
                                if header.resyncable(max_frame_bytes) {
                                    // The declared len is trustworthy even
                                    // though the header is not: consume the
                                    // payload and keep the connection alive.
                                    read_payload(&mut reader, &mut payload, header.len)?;
                                    (reply, None)
                                } else {
                                    // Can't tell where the payload ends —
                                    // the only safe resync point is a
                                    // fresh connection.
                                    writer.write_all(reply.as_bytes())?;
                                    writer.write_all(b"\n")?;
                                    return Ok(());
                                }
                            }
                            None => {
                                read_payload(&mut reader, &mut payload, header.len)?;
                                stats.frames_received.fetch_add(1, Ordering::Relaxed);
                                stats
                                    .frame_bytes
                                    .fetch_add(header.len as u64, Ordering::Relaxed);
                                infer_reply(
                                    coord,
                                    id,
                                    model.as_deref(),
                                    &PixelSource::Frame(&header, &payload),
                                    wire_key,
                                    slo,
                                    span,
                                    fmt,
                                )
                            }
                        }
                    }
                    image => infer_reply(
                        coord,
                        id,
                        model.as_deref(),
                        &PixelSource::Spec(&image),
                        wire_key,
                        slo,
                        span,
                        fmt,
                    ),
                }
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        // The reply bytes are handed to the kernel: stamp the final
        // stage and retire the timeline.  Lane keyed by request id —
        // this plane has no fixed IO threads to key by.
        if let Some(mut s) = span {
            s.set(Stage::ReplyFlushed, coord.obs().now_ns());
            coord.obs().complete(&mut s, s.id as usize);
        }
    }
}

/// One inference request end-to-end, blocking this connection's thread
/// on the reply channel (the behavior the event plane's completion
/// queue replaces).  Resolve the model (structured reject on unknown
/// names — never a default fallback), consult the per-model wire-key
/// cache, decode into the model's arena, submit.
///
/// A hot reload can retire the resolved generation between resolve and
/// route (`SubmitError::Closed`); the retry re-resolves and resubmits
/// the **already-decoded pixels** (handed back by
/// [`Coordinator::submit_on_reclaim`]) to the fresh generation —
/// decode runs again only in the rare case where the reload changed
/// the model's input size, so the swap stays invisible to the client
/// without paying a second decode.
fn infer_reply(
    coord: &Coordinator,
    id: u64,
    model: Option<&str>,
    src: &PixelSource<'_>,
    wire_key: Option<u64>,
    slo: Slo,
    span: Span,
    fmt: protocol::ReplyFmt,
) -> (String, Option<Span>) {
    const ATTEMPTS: usize = 2;
    let mut decoded: Option<PooledTensor> = None;
    for attempt in 0..ATTEMPTS {
        let lease = match coord.lease(model) {
            Ok(l) => l,
            Err(e @ SubmitError::UnknownModel(_)) => {
                return (
                    fmt.error_line_kind(id, "unknown_model", &e.to_string()),
                    None,
                )
            }
            Err(e @ SubmitError::ModelUnavailable { .. }) => {
                return (
                    fmt.error_line_kind(id, "model_unavailable", &e.to_string()),
                    None,
                )
            }
            Err(e) => return (fmt.error_line(id, &e.to_string()), None),
        };
        // Wire-key fast path: a repeat of the same raw image spec is
        // answered from this model's response cache before any pixel is
        // decoded (the key was hashed straight off the request's value
        // span).  Per-model caches make the key collision-free across
        // models by construction.
        if let Some(mut resp) = wire_key.and_then(|k| lease.cached_response(k)) {
            resp.id = id;
            let mut s = span;
            s.id = id;
            s.flags |= flag::CACHE_HIT;
            return (fmt.response_line(&resp), Some(s));
        }
        // Reuse the pixels reclaimed from a Closed first attempt when
        // they still fit the (possibly re-sized) fresh generation.
        let hw = lease.input_hw();
        let tensor = match decoded.take().filter(|t| t.shape() == [hw, hw, 3]) {
            Some(t) => t,
            None => match super::load_pixels(src, hw, &lease.arena()) {
                Err(e) => {
                    return (fmt.error_line(id, &format!("image: {e}")), None)
                }
                Ok(t) => t,
            },
        };
        // Span is Copy: a Closed retry re-submits the same timeline.
        return match coord.submit_on_reclaim_traced(&lease, tensor, slo, wire_key, span)
        {
            Err((SubmitError::Closed, img)) if attempt + 1 < ATTEMPTS => {
                decoded = img;
                continue;
            }
            Err((SubmitError::Overloaded, _)) => {
                (fmt.error_line_kind(id, "overloaded", "overloaded"), None)
            }
            Err((
                SubmitError::Shed {
                    predicted_ms,
                    deadline_ms,
                },
                _,
            )) => (fmt.shed_line(id, predicted_ms, deadline_ms), None),
            Err((e, _)) => (fmt.error_line(id, &e.to_string()), None),
            Ok(rx) => match rx.recv() {
                Ok(mut resp) => {
                    resp.id = id; // echo client id, not internal id
                    (fmt.response_line(&resp), resp.span)
                }
                Err(_) => (fmt.error_line(id, "worker gone"), None),
            },
        };
    }
    (fmt.error_line(id, "closed"), None)
}
