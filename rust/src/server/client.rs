//! Line-protocol client — used by examples, the load generator, and the
//! server integration test.
//!
//! Inference goes through one request type: build an [`InferRequest`]
//! (id, then pixel source and any SLO/model fields), send it with
//! [`Client::infer`].  The old per-shape methods
//! (`infer_synthetic`, `infer_synthetic_model`, `infer_synthetic_slo`,
//! `infer_ppm`) survive as deprecated delegating shims.
//!
//! Binary frames: call [`Client::hello`] with `binary_frames = true`
//! once per connection, then [`InferRequest::frame`] requests ship
//! pixels as a raw length-prefixed payload instead of JSON.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::Json;

/// One parsed inference reply.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    pub ok: bool,
    pub top1: usize,
    pub total_ms: f64,
    pub exec_ms: f64,
    pub queue_ms: f64,
    pub batch: usize,
    /// Which engine served the request ("cache" for a cache hit).
    pub engine: String,
    /// Which registry model served the request ("" on errors).
    pub model: String,
    /// True when served from the response cache.
    pub cached: bool,
    /// Machine-matchable error kind ("shed", "overloaded", ...).
    pub kind: Option<String>,
    /// Human-readable error text (the `msg` field; falls back to the
    /// deprecated `error` alias for older servers).
    pub error: Option<String>,
}

/// Server handshake reply (`{"cmd":"hello"}`).
#[derive(Debug, Clone)]
pub struct HelloReply {
    pub protocol_version: u64,
    /// Capabilities the server advertises ("binary_frames",
    /// "wire_parser:tape", "plane:event", ...).
    pub features: Vec<String>,
    /// True when this connection may send binary pixel frames.
    pub binary_frames: bool,
}

/// Where an [`InferRequest`]'s pixels come from.
#[derive(Debug, Clone)]
enum Pixels {
    Synthetic(u64),
    Ppm(String),
    Frame {
        h: usize,
        w: usize,
        c: usize,
        bytes: Vec<u8>,
    },
}

/// One inference request, built field by field:
///
/// ```no_run
/// # use zuluko::server::client::{Client, InferRequest};
/// # fn demo(c: &mut Client) -> anyhow::Result<()> {
/// let reply = c.infer(
///     &InferRequest::new(7)
///         .model("resnet")
///         .deadline_ms(50.0)
///         .synthetic(42),
/// )?;
/// # Ok(()) }
/// ```
///
/// Exactly one pixel source must be set ([`synthetic`], [`ppm`], or
/// [`frame`] — last call wins); [`Client::infer`] rejects a request
/// without one.
///
/// [`synthetic`]: InferRequest::synthetic
/// [`ppm`]: InferRequest::ppm
/// [`frame`]: InferRequest::frame
#[derive(Debug, Clone)]
pub struct InferRequest {
    id: u64,
    model: Option<String>,
    deadline_ms: Option<f64>,
    priority: Option<String>,
    pixels: Option<Pixels>,
}

impl InferRequest {
    pub fn new(id: u64) -> InferRequest {
        InferRequest {
            id,
            model: None,
            deadline_ms: None,
            priority: None,
            pixels: None,
        }
    }

    /// Address a registry model (default: the server's default model).
    pub fn model(mut self, model: &str) -> Self {
        self.model = Some(model.to_string());
        self
    }

    /// SLO deadline in milliseconds.
    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// SLO priority class (e.g. "high").
    pub fn priority(mut self, priority: &str) -> Self {
        self.priority = Some(priority.to_string());
        self
    }

    /// Pixels: a server-side seeded synthetic image.
    pub fn synthetic(mut self, seed: u64) -> Self {
        self.pixels = Some(Pixels::Synthetic(seed));
        self
    }

    /// Pixels: a PPM file (path as seen by the *server*).
    pub fn ppm(mut self, path: &str) -> Self {
        self.pixels = Some(Pixels::Ppm(path.to_string()));
        self
    }

    /// Pixels: raw u8 RGB (row-major HWC), shipped as a binary frame.
    /// Requires a [`Client::hello`] negotiation first; `bytes.len()`
    /// must equal `h * w * c`.
    pub fn frame(mut self, h: usize, w: usize, c: usize, bytes: &[u8]) -> Self {
        self.pixels = Some(Pixels::Frame {
            h,
            w,
            c,
            bytes: bytes.to_vec(),
        });
        self
    }

    /// Encode to the wire: the JSON request line plus, for frame
    /// requests, the raw payload to ship right after it.  Public so
    /// tests can assert the exact encoding without a socket.
    pub fn request_line(&self) -> Result<(String, Option<&[u8]>)> {
        let mut img = Json::obj();
        let payload = match &self.pixels {
            None => bail!("InferRequest needs a pixel source: synthetic(), ppm(), or frame()"),
            Some(Pixels::Synthetic(seed)) => {
                img.set("synthetic", (*seed).into());
                None
            }
            Some(Pixels::Ppm(path)) => {
                img.set("ppm", path.as_str().into());
                None
            }
            Some(Pixels::Frame { h, w, c, bytes }) => {
                let mut fr = Json::obj();
                fr.set("len", bytes.len().into());
                fr.set("h", (*h).into());
                fr.set("w", (*w).into());
                fr.set("c", (*c).into());
                fr.set("dtype", "u8".into());
                img.set("frame", fr);
                Some(bytes.as_slice())
            }
        };
        let mut o = Json::obj();
        o.set("id", self.id.into()).set("image", img);
        if let Some(m) = &self.model {
            o.set("model", m.as_str().into());
        }
        if let Some(ms) = self.deadline_ms {
            o.set("deadline_ms", ms.into());
        }
        if let Some(p) = &self.priority {
            o.set("priority", p.as_str().into());
        }
        Ok((o.to_string(), payload))
    }
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Reused reply-line buffer (one warm allocation per client, not one
    /// per request).
    replybuf: String,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            replybuf: String::new(),
        })
    }

    fn read_reply(&mut self) -> Result<Json> {
        self.replybuf.clear();
        if self.reader.read_line(&mut self.replybuf)? == 0 {
            bail!("server closed connection");
        }
        Json::parse(&self.replybuf).map_err(|e| anyhow::anyhow!("{e}"))
    }

    fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.read_reply()
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(r#"{"cmd":"ping"}"#)?;
        Ok(j.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    /// Protocol handshake (`{"cmd":"hello"}`): learn the server's
    /// protocol version and feature set, and opt in to binary pixel
    /// frames.  Negotiation is sticky for the connection's lifetime.
    pub fn hello(&mut self, binary_frames: bool) -> Result<HelloReply> {
        let line = if binary_frames {
            r#"{"cmd":"hello","features":{"binary_frames":true}}"#
        } else {
            r#"{"cmd":"hello"}"#
        };
        let j = self.roundtrip(line)?;
        if !j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false) {
            bail!("hello rejected: {}", j.to_string());
        }
        Ok(HelloReply {
            protocol_version: j
                .get("protocol_version")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u64,
            features: j
                .get("features")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|f| f.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
            binary_frames: j
                .get("negotiated")
                .and_then(|n| n.get("binary_frames"))
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
        })
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"stats"}"#)
    }

    /// Unified metrics snapshot (`{"cmd":"metrics"}`): stats plus
    /// per-stage histograms, trace counters, and process health.
    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"metrics"}"#)
    }

    /// Last-`n` retained request timelines plus the anomaly slow log
    /// (`{"cmd":"trace"}`).
    pub fn trace(&mut self, n: usize) -> Result<Json> {
        self.roundtrip(&format!(r#"{{"cmd":"trace","n":{n}}}"#))
    }

    /// Policy-layer introspection (`{"cmd":"policy"}`).
    pub fn policy(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"policy"}"#)
    }

    /// Registry listing (`{"cmd":"models"}`).
    pub fn models(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"models"}"#)
    }

    /// Hot reload a model's artifacts (`None` = default model).
    pub fn reload(&mut self, model: Option<&str>) -> Result<Json> {
        let mut o = Json::obj();
        o.set("cmd", "reload".into());
        if let Some(m) = model {
            o.set("model", m.into());
        }
        self.roundtrip(&o.to_string())
    }

    /// Send one inference request and wait for its reply.  Frame
    /// requests ship the header line and the raw payload back to back
    /// (one write each — the server's framing layer reassembles).
    pub fn infer(&mut self, req: &InferRequest) -> Result<InferReply> {
        let (line, payload) = req.request_line()?;
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        if let Some(bytes) = payload {
            self.writer.write_all(bytes)?;
        }
        let j = self.read_reply()?;
        Ok(parse_reply(&j))
    }

    /// Infer on a seeded synthetic image.
    #[deprecated(since = "0.1.0", note = "use Client::infer(&InferRequest::new(id).synthetic(seed))")]
    pub fn infer_synthetic(&mut self, id: u64, seed: u64) -> Result<InferReply> {
        self.infer(&InferRequest::new(id).synthetic(seed))
    }

    /// Infer on a seeded synthetic image, addressed to a registry model
    /// (`None` = the server's default model).
    #[deprecated(since = "0.1.0", note = "use Client::infer with InferRequest::model")]
    pub fn infer_synthetic_model(
        &mut self,
        id: u64,
        seed: u64,
        model: Option<&str>,
    ) -> Result<InferReply> {
        let mut req = InferRequest::new(id).synthetic(seed);
        if let Some(m) = model {
            req = req.model(m);
        }
        self.infer(&req)
    }

    /// Infer on a seeded synthetic image with an SLO (deadline and/or
    /// priority).
    #[deprecated(
        since = "0.1.0",
        note = "use Client::infer with InferRequest::deadline_ms/priority"
    )]
    pub fn infer_synthetic_slo(
        &mut self,
        id: u64,
        seed: u64,
        deadline_ms: Option<f64>,
        priority: Option<&str>,
    ) -> Result<InferReply> {
        let mut req = InferRequest::new(id).synthetic(seed);
        if let Some(ms) = deadline_ms {
            req = req.deadline_ms(ms);
        }
        if let Some(p) = priority {
            req = req.priority(p);
        }
        self.infer(&req)
    }

    /// Infer on a PPM file (path as seen by the *server*).
    #[deprecated(since = "0.1.0", note = "use Client::infer(&InferRequest::new(id).ppm(path))")]
    pub fn infer_ppm(&mut self, id: u64, path: &str) -> Result<InferReply> {
        self.infer(&InferRequest::new(id).ppm(path))
    }
}

fn parse_reply(j: &Json) -> InferReply {
    InferReply {
        id: j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        ok: j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false),
        top1: j.get("top1").and_then(|v| v.as_usize()).unwrap_or(0),
        total_ms: j.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        exec_ms: j.get("exec_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        queue_ms: j.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
        engine: j
            .get("engine")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        model: j
            .get("model")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        cached: j.get("cached").and_then(|v| v.as_bool()).unwrap_or(false),
        kind: j
            .get("kind")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
        error: j
            .get("msg")
            .or_else(|| j.get("error"))
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_encodes_every_field() {
        let (line, payload) = InferRequest::new(7)
            .model("resnet")
            .deadline_ms(50.0)
            .priority("high")
            .synthetic(42)
            .request_line()
            .unwrap();
        assert_eq!(
            line,
            r#"{"deadline_ms":50,"id":7,"image":{"synthetic":42},"model":"resnet","priority":"high"}"#
        );
        assert!(payload.is_none());
    }

    #[test]
    fn builder_frame_emits_header_and_payload() {
        let bytes = [1u8, 2, 3, 4, 5, 6];
        let req = InferRequest::new(1).frame(1, 2, 3, &bytes);
        let (line, payload) = req.request_line().unwrap();
        assert_eq!(
            line,
            r#"{"id":1,"image":{"frame":{"c":3,"dtype":"u8","h":1,"len":6,"w":2}}}"#
        );
        assert_eq!(payload, Some(&bytes[..]));
    }

    #[test]
    fn builder_ppm_matches_legacy_encoding() {
        let (line, payload) =
            InferRequest::new(3).ppm("/tmp/x.ppm").request_line().unwrap();
        assert_eq!(line, r#"{"id":3,"image":{"ppm":"/tmp/x.ppm"}}"#);
        assert!(payload.is_none());
    }

    #[test]
    fn builder_without_pixels_is_rejected() {
        assert!(InferRequest::new(1).request_line().is_err());
    }

    #[test]
    fn builder_last_pixel_source_wins() {
        let (line, _) = InferRequest::new(1)
            .ppm("/x")
            .synthetic(9)
            .request_line()
            .unwrap();
        assert_eq!(line, r#"{"id":1,"image":{"synthetic":9}}"#);
    }
}
