//! Line-protocol client — used by examples, the load generator, and the
//! server integration test.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::Json;

/// One parsed inference reply.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    pub ok: bool,
    pub top1: usize,
    pub total_ms: f64,
    pub exec_ms: f64,
    pub queue_ms: f64,
    pub batch: usize,
    /// Which engine served the request ("cache" for a cache hit).
    pub engine: String,
    /// Which registry model served the request ("" on errors).
    pub model: String,
    /// True when served from the response cache.
    pub cached: bool,
    /// Machine-matchable error kind ("shed", "overloaded", ...).
    pub kind: Option<String>,
    pub error: Option<String>,
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Reused reply-line buffer (one warm allocation per client, not one
    /// per request).
    replybuf: String,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            replybuf: String::new(),
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.replybuf.clear();
        if self.reader.read_line(&mut self.replybuf)? == 0 {
            bail!("server closed connection");
        }
        Json::parse(&self.replybuf).map_err(|e| anyhow::anyhow!("{e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(r#"{"cmd":"ping"}"#)?;
        Ok(j.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"stats"}"#)
    }

    /// Unified metrics snapshot (`{"cmd":"metrics"}`): stats plus
    /// per-stage histograms, trace counters, and process health.
    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"metrics"}"#)
    }

    /// Last-`n` retained request timelines plus the anomaly slow log
    /// (`{"cmd":"trace"}`).
    pub fn trace(&mut self, n: usize) -> Result<Json> {
        self.roundtrip(&format!(r#"{{"cmd":"trace","n":{n}}}"#))
    }

    /// Policy-layer introspection (`{"cmd":"policy"}`).
    pub fn policy(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"policy"}"#)
    }

    /// Infer on a seeded synthetic image.
    pub fn infer_synthetic(&mut self, id: u64, seed: u64) -> Result<InferReply> {
        let line = format!(r#"{{"id":{id},"image":{{"synthetic":{seed}}}}}"#);
        let j = self.roundtrip(&line)?;
        Ok(parse_reply(&j))
    }

    /// Infer on a seeded synthetic image, addressed to a registry model
    /// (`None` = the server's default model).
    pub fn infer_synthetic_model(
        &mut self,
        id: u64,
        seed: u64,
        model: Option<&str>,
    ) -> Result<InferReply> {
        let mut img = Json::obj();
        img.set("synthetic", seed.into());
        let mut o = Json::obj();
        o.set("id", id.into()).set("image", img);
        if let Some(m) = model {
            o.set("model", m.into());
        }
        let j = self.roundtrip(&o.to_string())?;
        Ok(parse_reply(&j))
    }

    /// Registry listing (`{"cmd":"models"}`).
    pub fn models(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"models"}"#)
    }

    /// Hot reload a model's artifacts (`None` = default model).
    pub fn reload(&mut self, model: Option<&str>) -> Result<Json> {
        let mut o = Json::obj();
        o.set("cmd", "reload".into());
        if let Some(m) = model {
            o.set("model", m.into());
        }
        self.roundtrip(&o.to_string())
    }

    /// Infer on a seeded synthetic image with an SLO (deadline and/or
    /// priority).
    pub fn infer_synthetic_slo(
        &mut self,
        id: u64,
        seed: u64,
        deadline_ms: Option<f64>,
        priority: Option<&str>,
    ) -> Result<InferReply> {
        let mut img = Json::obj();
        img.set("synthetic", seed.into());
        let mut o = Json::obj();
        o.set("id", id.into()).set("image", img);
        if let Some(ms) = deadline_ms {
            o.set("deadline_ms", ms.into());
        }
        if let Some(p) = priority {
            o.set("priority", p.into());
        }
        let j = self.roundtrip(&o.to_string())?;
        Ok(parse_reply(&j))
    }

    /// Infer on a PPM file (path as seen by the *server*).
    pub fn infer_ppm(&mut self, id: u64, path: &str) -> Result<InferReply> {
        let mut img = Json::obj();
        img.set("ppm", path.into());
        let mut o = Json::obj();
        o.set("id", id.into()).set("image", img);
        let j = self.roundtrip(&o.to_string())?;
        Ok(parse_reply(&j))
    }
}

fn parse_reply(j: &Json) -> InferReply {
    InferReply {
        id: j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        ok: j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false),
        top1: j.get("top1").and_then(|v| v.as_usize()).unwrap_or(0),
        total_ms: j.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        exec_ms: j.get("exec_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        queue_ms: j.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
        engine: j
            .get("engine")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        model: j
            .get("model")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        cached: j.get("cached").and_then(|v| v.as_bool()).unwrap_or(false),
        kind: j
            .get("kind")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
        error: j
            .get("error")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
    }
}
