//! Config system: JSON file + CLI overrides -> validated `Config`.
//!
//! Precedence: defaults < `--config file.json` < individual CLI flags.
//! Every field is validated at startup (fail fast, never mid-request).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::engine::EngineKind;
use crate::util::cli::Args;
use crate::util::json::Json;

/// SLO policy knobs (DESIGN.md §7).
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Run two engine queues (configured engine + int8 quant path) with
    /// per-request adaptive selection.
    pub adaptive: bool,
    /// Legacy knob from the per-pool-worker era: the shared runtime
    /// serves every queue from one fixed fleet, so this no longer
    /// allocates threads.  Parsed and validated for config
    /// compatibility; ignored by the scheduler.
    pub quant_workers: usize,
    /// Response-cache entries (0 disables the cache).
    pub cache_capacity: usize,
    /// EWMA weight of the newest latency sample, in (0, 1].
    pub ewma_alpha: f64,
    /// Headroom multiplier on predictions before deadline admission
    /// (>= 1; higher sheds earlier).
    pub margin: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            adaptive: false,
            quant_workers: 1,
            cache_capacity: 0,
            ewma_alpha: 0.2,
            margin: 1.2,
        }
    }
}

/// Tensor-arena knobs (DESIGN.md §"Memory ownership on the hot path").
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Reuse request/batch buffers through the tensor pool.  `false` is
    /// the allocation-ablation mode: identical code path, every lease
    /// allocates fresh.
    pub enabled: bool,
    /// Default max retained buffers per size class (bound on pool
    /// memory).  The coordinator's startup reservations may raise the
    /// bound for specific classes: the decode class is reserved at
    /// `queue_capacity` so a full admission queue of in-flight leases
    /// still returns into the arena.
    pub per_class_cap: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            enabled: true,
            per_class_cap: 16,
        }
    }
}

/// Multi-model registry knobs (DESIGN.md §8).
///
/// Empty `models` means single-model mode: one model named
/// [`RegistryConfig::SINGLE_MODEL`] served from `Config::artifacts`
/// (full backward compatibility — requests without a `model` field
/// behave exactly as before the registry existed).
#[derive(Debug, Clone, Default)]
pub struct RegistryConfig {
    /// Registered models in declaration order: (name, artifacts dir).
    pub models: Vec<(String, PathBuf)>,
    /// Which model serves requests that carry no `model` field.
    /// `None` is only valid for 0–1 registered models (validate()
    /// refuses to guess among several: JSON sources don't preserve
    /// declaration order, so "first" would mean "alphabetical").
    pub default_model: Option<String>,
    /// Build + warm every model's engine pools at startup instead of
    /// lazily on first request (trades startup time for first-request
    /// latency).
    pub preload: bool,
    /// Per-model fair-share weights for the shared worker runtime
    /// (models.json `"weights"` / `--model-weight name=w`).  A model
    /// absent here weighs 1.0; under saturation each backlogged model
    /// receives service proportional to its weight.
    pub weights: Vec<(String, f64)>,
}

impl RegistryConfig {
    /// Name of the implicit model in single-model mode.
    pub const SINGLE_MODEL: &'static str = "default";

    /// Register or replace a model (CLI `--model name=path` overrides a
    /// models.json entry of the same name).
    pub fn upsert(&mut self, name: &str, path: PathBuf) {
        match self.models.iter_mut().find(|(n, _)| n == name) {
            Some(slot) => slot.1 = path,
            None => self.models.push((name.to_string(), path)),
        }
    }

    /// Apply a `"weights"` JSON object (name -> number) — shared by the
    /// config file's `registry` section and a models.json index so the
    /// two sources can't drift.
    pub fn apply_weights_json(&mut self, ws: &Json) -> Result<()> {
        let obj = ws.as_obj().ok_or_else(|| {
            anyhow::anyhow!("registry \"weights\" must be an object of name -> number")
        })?;
        for (name, v) in obj {
            match v.as_f64() {
                Some(w) => self.set_weight(name, w),
                None => bail!("weight for model '{name}' must be a number"),
            }
        }
        Ok(())
    }

    /// Set or replace a model's scheduler weight.
    pub fn set_weight(&mut self, name: &str, weight: f64) {
        match self.weights.iter_mut().find(|(n, _)| n == name) {
            Some(slot) => slot.1 = weight,
            None => self.weights.push((name.to_string(), weight)),
        }
    }

    /// The shared-runtime fair-share weight for `name` (1.0 default).
    pub fn weight_for(&self, name: &str) -> f64 {
        self.weights
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, w)| *w)
            .unwrap_or(1.0)
    }

    /// The effective default model name.
    pub fn effective_default(&self) -> &str {
        if let Some(d) = &self.default_model {
            return d;
        }
        self.models
            .first()
            .map(|(n, _)| n.as_str())
            .unwrap_or(Self::SINGLE_MODEL)
    }

    /// Load a `models.json` index:
    /// `{"default": "name", "preload": true, "models": {"name": "path"}}`.
    /// Relative paths resolve against the index file's directory.
    pub fn load_index(path: &Path) -> Result<RegistryConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading models index {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let base = path.parent().unwrap_or(Path::new("."));
        let mut reg = RegistryConfig::default();
        let models = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "models index {} needs a \"models\" object of name -> path",
                    path.display()
                )
            })?;
        for (name, v) in models {
            let p = v.as_str().ok_or_else(|| {
                anyhow::anyhow!("model '{name}': path must be a string")
            })?;
            let p = Path::new(p);
            let abs = if p.is_absolute() {
                p.to_path_buf()
            } else {
                base.join(p)
            };
            reg.upsert(name, abs);
        }
        if let Some(d) = j.get("default").and_then(|v| v.as_str()) {
            reg.default_model = Some(d.to_string());
        }
        if let Some(p) = j.get("preload").and_then(|v| v.as_bool()) {
            reg.preload = p;
        }
        if let Some(ws) = j.get("weights") {
            reg.apply_weights_json(ws)?;
        }
        Ok(reg)
    }
}

/// Which connection-plane architecture `zuluko serve` runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConnPlane {
    /// Epoll reactor: fixed IO thread set multiplexing non-blocking
    /// connections with async worker completions (the default).
    #[default]
    Event,
    /// Thread-per-connection ablation baseline (E13): one blocking OS
    /// thread per socket, as before the reactor existed.
    Threads,
}

impl ConnPlane {
    pub fn parse(s: &str) -> Result<ConnPlane> {
        match s {
            "event" => Ok(ConnPlane::Event),
            "threads" => Ok(ConnPlane::Threads),
            other => bail!("--conn-plane expects event|threads, got '{other}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ConnPlane::Event => "event",
            ConnPlane::Threads => "threads",
        }
    }
}

impl std::fmt::Display for ConnPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Replica-snapshot policy (DESIGN.md §"Replica snapshots").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapshotMode {
    /// Load `.zsnap` files when valid, write one after every cold probe
    /// build (the default).
    #[default]
    On,
    /// Never read or write snapshots — the cold-build ablation; byte-
    /// for-byte the pre-snapshot behavior.
    Off,
    /// Ignore any existing snapshot, cold-build, and rewrite it —
    /// operator escape hatch for a suspect snapshot file.
    Refresh,
}

impl SnapshotMode {
    pub fn parse(s: &str) -> Result<SnapshotMode> {
        match s {
            "on" => Ok(SnapshotMode::On),
            "off" => Ok(SnapshotMode::Off),
            "refresh" => Ok(SnapshotMode::Refresh),
            other => bail!("--snapshots expects on|off|refresh, got '{other}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SnapshotMode::On => "on",
            SnapshotMode::Off => "off",
            SnapshotMode::Refresh => "refresh",
        }
    }

    /// May replica builds consume an existing snapshot?
    pub fn reads(&self) -> bool {
        matches!(self, SnapshotMode::On)
    }

    /// Should a cold probe build write a fresh snapshot?
    pub fn writes(&self) -> bool {
        !matches!(self, SnapshotMode::Off)
    }
}

impl std::fmt::Display for SnapshotMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which request-line parser the serving planes run (DESIGN.md §"Wire
/// plane").  Both produce identical messages and diagnostics; the flag
/// exists so the tree baseline stays measurable (E15 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireParser {
    /// Tape scanner: iterative bounded-depth scan over the pooled read
    /// buffer, sparse field extraction, zero steady-state allocations
    /// (the default).
    #[default]
    Tape,
    /// Legacy `Json` tree parser on the wire path (E15 baseline).
    Tree,
}

impl WireParser {
    pub fn parse(s: &str) -> Result<WireParser> {
        match s {
            "tape" => Ok(WireParser::Tape),
            "tree" => Ok(WireParser::Tree),
            other => bail!("--wire-parser expects tape|tree, got '{other}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WireParser::Tape => "tape",
            WireParser::Tree => "tree",
        }
    }
}

impl std::fmt::Display for WireParser {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Connection-plane knobs for `zuluko serve` (DESIGN.md §"Connection
/// plane").
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub conn_plane: ConnPlane,
    /// Event plane: IO threads multiplexing the connection set.  Two
    /// saturate the newline-JSON protocol well past 10k connections;
    /// the knob exists for the E13 scaling axis.
    pub io_threads: usize,
    /// Open-connection cap.  Beyond it, new sockets get a structured
    /// `at_capacity` line and close.  (The threads plane spends one OS
    /// thread per connection — size accordingly for ablation runs.)
    pub max_connections: usize,
    /// Per-request line budget in bytes; longer lines are a structured
    /// `bad_request` reject + close (OOM-DoS bound).
    pub max_line_bytes: usize,
    /// Binary frame payload budget in bytes (negotiated connections
    /// only); a declared frame over the bound is a structured
    /// `bad_frame` reject + close — the framing layer won't buffer it.
    pub max_frame_bytes: usize,
    /// Evict connections idle this long (0 disables; event plane only).
    pub idle_timeout_ms: u64,
    /// Request-line parser: tape scanner (default) or the legacy tree
    /// parser kept as the E15 ablation baseline.
    pub wire_parser: WireParser,
    /// Emit the deprecated duplicate `error` field (alias of `msg`) on
    /// error lines, for clients not yet reading the PR 9 unified schema.
    /// Off by default — the alias was kept "for one release" and this
    /// flag is its sunset path.
    pub compat_error_alias: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            conn_plane: ConnPlane::Event,
            io_threads: 2,
            max_connections: 1024,
            max_line_bytes: 64 * 1024,
            // A 1024x1024 RGB u8 frame is 3 MiB; 8 MiB leaves headroom
            // without letting one client pin the read buffer pool.
            max_frame_bytes: 8 * 1024 * 1024,
            idle_timeout_ms: 60_000,
            wire_parser: WireParser::Tape,
            compat_error_alias: false,
        }
    }
}

/// Observability knobs (DESIGN.md §10): request-lifecycle tracing and
/// the unified metrics plane.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Head-sampling rate in [0, 1]: the fraction of requests whose
    /// span timeline is recorded into the trace rings.  0 keeps tracing
    /// compiled in but records nothing; anomalies (shed, deadline
    /// missed, slowest tail) are always captured regardless.
    pub trace_sample_rate: f64,
    /// Capacity of each per-worker/per-IO-lane trace ring, in spans.
    pub trace_ring: usize,
    /// Capacity of the always-capture anomaly slow log, in spans.
    pub slow_log: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace_sample_rate: 0.01,
            trace_ring: 1024,
            slow_log: 256,
        }
    }
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifacts directory (manifest.json + *.hlo.txt).
    pub artifacts: PathBuf,
    /// Which engine backend serves requests.
    pub engine: EngineKind,
    /// Size of the shared worker runtime: a fixed, process-wide fleet
    /// of threads serving every (model, engine) queue — NOT a per-pool
    /// count.  Defaults to the detected core count (clamped ≥ 1); set
    /// via `--workers` / `--runtime-workers`.
    pub workers: usize,
    /// Byte budget (in MB) of each runtime worker's engine-replica LRU
    /// cache — bounds resident weights when one worker serves many
    /// models.  A single replica larger than the budget is kept alone.
    pub replica_cache_mb: usize,
    /// Replica-snapshot policy: `on` loads/writes `.zsnap` files so
    /// cold replica builds become load-and-validate; `off` is the
    /// cold-build ablation; `refresh` rebuilds and rewrites.
    pub snapshots: SnapshotMode,
    /// Predictive warm-up: when a cold (model, engine) queue's EWMA
    /// arrival rate (requests/sec) crosses this threshold, idle workers
    /// prefetch-build its replica before traffic lands.  0 disables.
    pub prefetch_threshold: f64,
    /// Dynamic batcher: max images per batch (must have an artifact).
    pub max_batch: usize,
    /// Dynamic batcher: how long to wait for a batch to fill.
    pub batch_timeout: Duration,
    /// Admission queue capacity **per (model, engine) queue** —
    /// requests beyond this are rejected (backpressure instead of
    /// unbounded memory).  Pre-runtime versions kept one queue per
    /// pool worker, so effective buffering was `workers ×` this;
    /// the shared runtime has exactly one queue per (model, engine),
    /// making this the precise admission bound.
    pub queue_capacity: usize,
    /// TCP listen address for `zuluko serve`.
    pub listen: String,
    /// Log level (0=error..3=debug).
    pub log_level: u8,
    /// SLO policy layer knobs.
    pub policy: PolicyConfig,
    /// Hot-path buffer pool knobs.
    pub pool: PoolConfig,
    /// Multi-model registry knobs.
    pub registry: RegistryConfig,
    /// Connection-plane knobs for `zuluko serve`.
    pub server: ServerConfig,
    /// Request-lifecycle tracing knobs.
    pub obs: ObsConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts: crate::artifacts_dir(),
            engine: EngineKind::AclStaged,
            // Work-conserving shared runtime: one worker per detected
            // core (the embedded budget the scheduler divides), never 0.
            workers: crate::metrics::sysmon::num_cpus().max(1),
            replica_cache_mb: 128,
            snapshots: SnapshotMode::On,
            prefetch_threshold: 0.0,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 64,
            listen: "127.0.0.1:7878".to_string(),
            log_level: crate::util::log::INFO,
            policy: PolicyConfig::default(),
            pool: PoolConfig::default(),
            registry: RegistryConfig::default(),
            server: ServerConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl Config {
    /// Load from a JSON file (all fields optional).
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut c = Config::default();
        c.apply_json(&j)?;
        Ok(c)
    }

    fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("artifacts").and_then(|v| v.as_str()) {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("engine").and_then(|v| v.as_str()) {
            self.engine = EngineKind::parse(v)?;
        }
        if let Some(v) = j.get("workers").and_then(|v| v.as_usize()) {
            self.workers = v;
        }
        // `runtime_workers` is the explicit name for the same knob
        // (the shared runtime's fleet size); it wins over `workers`.
        if let Some(v) = j.get("runtime_workers").and_then(|v| v.as_usize()) {
            self.workers = v;
        }
        if let Some(v) = j.get("replica_cache_mb").and_then(|v| v.as_usize()) {
            self.replica_cache_mb = v;
        }
        if let Some(v) = j.get("snapshots").and_then(|v| v.as_str()) {
            self.snapshots = SnapshotMode::parse(v)?;
        }
        if let Some(v) = j.get("prefetch_threshold").and_then(|v| v.as_f64()) {
            self.prefetch_threshold = v;
        }
        if let Some(v) = j.get("max_batch").and_then(|v| v.as_usize()) {
            self.max_batch = v;
        }
        if let Some(v) = j.get("batch_timeout_ms").and_then(|v| v.as_f64()) {
            self.batch_timeout = Duration::from_secs_f64(v / 1e3);
        }
        if let Some(v) = j.get("queue_capacity").and_then(|v| v.as_usize()) {
            self.queue_capacity = v;
        }
        if let Some(v) = j.get("listen").and_then(|v| v.as_str()) {
            self.listen = v.to_string();
        }
        if let Some(v) = j.get("log_level").and_then(|v| v.as_usize()) {
            self.log_level = v as u8;
        }
        // Policy knobs live under a nested "policy" object.
        if let Some(p) = j.get("policy") {
            if let Some(v) = p.get("adaptive").and_then(|v| v.as_bool()) {
                self.policy.adaptive = v;
            }
            if let Some(v) = p.get("quant_workers").and_then(|v| v.as_usize()) {
                self.policy.quant_workers = v;
            }
            if let Some(v) = p.get("cache_capacity").and_then(|v| v.as_usize()) {
                self.policy.cache_capacity = v;
            }
            if let Some(v) = p.get("ewma_alpha").and_then(|v| v.as_f64()) {
                self.policy.ewma_alpha = v;
            }
            if let Some(v) = p.get("margin").and_then(|v| v.as_f64()) {
                self.policy.margin = v;
            }
        }
        // Pool knobs live under a nested "pool" object.
        if let Some(p) = j.get("pool") {
            if let Some(v) = p.get("enabled").and_then(|v| v.as_bool()) {
                self.pool.enabled = v;
            }
            if let Some(v) = p.get("per_class_cap").and_then(|v| v.as_usize()) {
                self.pool.per_class_cap = v;
            }
        }
        // Connection-plane knobs live under a nested "server" object.
        if let Some(s) = j.get("server") {
            if let Some(v) = s.get("conn_plane").and_then(|v| v.as_str()) {
                self.server.conn_plane = ConnPlane::parse(v)?;
            }
            if let Some(v) = s.get("io_threads").and_then(|v| v.as_usize()) {
                self.server.io_threads = v;
            }
            if let Some(v) = s.get("max_connections").and_then(|v| v.as_usize()) {
                self.server.max_connections = v;
            }
            if let Some(v) = s.get("max_line_bytes").and_then(|v| v.as_usize()) {
                self.server.max_line_bytes = v;
            }
            if let Some(v) = s.get("max_frame_bytes").and_then(|v| v.as_usize()) {
                self.server.max_frame_bytes = v;
            }
            if let Some(v) = s.get("idle_timeout_ms").and_then(|v| v.as_usize()) {
                self.server.idle_timeout_ms = v as u64;
            }
            if let Some(v) = s.get("wire_parser").and_then(|v| v.as_str()) {
                self.server.wire_parser = WireParser::parse(v)?;
            }
            if let Some(v) = s.get("compat_error_alias").and_then(|v| v.as_bool()) {
                self.server.compat_error_alias = v;
            }
        }
        // Tracing knobs live under a nested "obs" object.
        if let Some(o) = j.get("obs") {
            if let Some(v) = o.get("trace_sample_rate").and_then(|v| v.as_f64()) {
                self.obs.trace_sample_rate = v;
            }
            if let Some(v) = o.get("trace_ring").and_then(|v| v.as_usize()) {
                self.obs.trace_ring = v;
            }
            if let Some(v) = o.get("slow_log").and_then(|v| v.as_usize()) {
                self.obs.slow_log = v;
            }
        }
        // Registry knobs live under a nested "registry" object with the
        // same shape as a models.json index.
        if let Some(r) = j.get("registry") {
            if let Some(models) = r.get("models").and_then(|m| m.as_obj()) {
                for (name, v) in models {
                    match v.as_str() {
                        Some(p) => self.registry.upsert(name, PathBuf::from(p)),
                        None => bail!("registry model '{name}': path must be a string"),
                    }
                }
            }
            if let Some(d) = r.get("default").and_then(|v| v.as_str()) {
                self.registry.default_model = Some(d.to_string());
            }
            if let Some(p) = r.get("preload").and_then(|v| v.as_bool()) {
                self.registry.preload = p;
            }
            if let Some(ws) = r.get("weights") {
                self.registry.apply_weights_json(ws)?;
            }
        }
        Ok(())
    }

    /// Apply CLI flag overrides (flags named like the JSON keys).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(v) = a.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = a.get("engine") {
            self.engine = EngineKind::parse(v)?;
        }
        self.workers = a.get_usize("workers", self.workers).map_err(anyhow::Error::msg)?;
        // --runtime-workers: explicit alias for the shared-runtime
        // fleet size (wins over --workers when both are given).
        self.workers = a
            .get_usize("runtime-workers", self.workers)
            .map_err(anyhow::Error::msg)?;
        self.replica_cache_mb = a
            .get_usize("replica-cache-mb", self.replica_cache_mb)
            .map_err(anyhow::Error::msg)?;
        // Strict enum parse — a typo'd mode must error, never silently
        // fall back to cold builds (same policy as --conn-plane).
        if let Some(v) = a.get("snapshots") {
            self.snapshots = SnapshotMode::parse(v)?;
        }
        self.prefetch_threshold = a
            .get_f64("prefetch-threshold", self.prefetch_threshold)
            .map_err(anyhow::Error::msg)?;
        self.max_batch = a
            .get_usize("max-batch", self.max_batch)
            .map_err(anyhow::Error::msg)?;
        let bt = a
            .get_f64(
                "batch-timeout-ms",
                self.batch_timeout.as_secs_f64() * 1e3,
            )
            .map_err(anyhow::Error::msg)?;
        self.batch_timeout = Duration::from_secs_f64(bt / 1e3);
        self.queue_capacity = a
            .get_usize("queue-capacity", self.queue_capacity)
            .map_err(anyhow::Error::msg)?;
        if let Some(v) = a.get("listen") {
            self.listen = v.to_string();
        }
        self.log_level = a
            .get_usize("log-level", self.log_level as usize)
            .map_err(anyhow::Error::msg)? as u8;
        if a.get("adaptive").is_some() {
            self.policy.adaptive = a.get_bool("adaptive");
        }
        self.policy.quant_workers = a
            .get_usize("quant-workers", self.policy.quant_workers)
            .map_err(anyhow::Error::msg)?;
        self.policy.cache_capacity = a
            .get_usize("cache-capacity", self.policy.cache_capacity)
            .map_err(anyhow::Error::msg)?;
        self.policy.ewma_alpha = a
            .get_f64("ewma-alpha", self.policy.ewma_alpha)
            .map_err(anyhow::Error::msg)?;
        self.policy.margin = a
            .get_f64("margin", self.policy.margin)
            .map_err(anyhow::Error::msg)?;
        // `--pool false` is the allocation-ablation switch.  Parsed
        // strictly: silently disabling pooling on a typo would skew any
        // benchmark or deployment that mistyped the flag.
        if let Some(v) = a.get("pool") {
            self.pool.enabled = match v {
                "true" | "1" | "yes" => true,
                "false" | "0" | "no" => false,
                other => bail!("--pool expects true|false, got '{other}'"),
            };
        }
        self.pool.per_class_cap = a
            .get_usize("pool-cap", self.pool.per_class_cap)
            .map_err(anyhow::Error::msg)?;
        // Connection plane.
        if let Some(v) = a.get("conn-plane") {
            self.server.conn_plane = ConnPlane::parse(v)?;
        }
        self.server.io_threads = a
            .get_usize("io-threads", self.server.io_threads)
            .map_err(anyhow::Error::msg)?;
        self.server.max_connections = a
            .get_usize("max-connections", self.server.max_connections)
            .map_err(anyhow::Error::msg)?;
        self.server.max_line_bytes = a
            .get_usize("max-line-bytes", self.server.max_line_bytes)
            .map_err(anyhow::Error::msg)?;
        self.server.max_frame_bytes = a
            .get_usize("max-frame-bytes", self.server.max_frame_bytes)
            .map_err(anyhow::Error::msg)?;
        self.server.idle_timeout_ms = a
            .get_usize("idle-timeout-ms", self.server.idle_timeout_ms as usize)
            .map_err(anyhow::Error::msg)? as u64;
        if let Some(v) = a.get("wire-parser") {
            self.server.wire_parser = WireParser::parse(v)?;
        }
        if a.get("compat-error-alias").is_some() {
            self.server.compat_error_alias = a.get_bool("compat-error-alias");
        }
        // Tracing.
        self.obs.trace_sample_rate = a
            .get_f64("trace-sample-rate", self.obs.trace_sample_rate)
            .map_err(anyhow::Error::msg)?;
        self.obs.trace_ring = a
            .get_usize("trace-ring", self.obs.trace_ring)
            .map_err(anyhow::Error::msg)?;
        self.obs.slow_log = a
            .get_usize("slow-log", self.obs.slow_log)
            .map_err(anyhow::Error::msg)?;
        // Registry: `--models index.json` loads a whole index, then
        // repeated `--model name=path` flags add/override entries.
        if let Some(p) = a.get("models") {
            let idx = RegistryConfig::load_index(Path::new(p))?;
            for (name, path) in idx.models {
                self.registry.upsert(&name, path);
            }
            if idx.default_model.is_some() {
                self.registry.default_model = idx.default_model;
            }
            if idx.preload {
                self.registry.preload = true;
            }
            for (name, w) in idx.weights {
                self.registry.set_weight(&name, w);
            }
        }
        for spec in a.get_all("model") {
            let (name, path) = spec.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--model expects name=path, got '{spec}'")
            })?;
            if name.is_empty() || path.is_empty() {
                bail!("--model expects name=path, got '{spec}'");
            }
            self.registry.upsert(name, PathBuf::from(path));
        }
        for spec in a.get_all("model-weight") {
            let (name, w) = spec.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--model-weight expects name=weight, got '{spec}'")
            })?;
            let w: f64 = w.parse().map_err(|_| {
                anyhow::anyhow!("--model-weight expects name=weight, got '{spec}'")
            })?;
            if name.is_empty() {
                bail!("--model-weight expects name=weight, got '{spec}'");
            }
            self.registry.set_weight(name, w);
        }
        if let Some(d) = a.get("default-model") {
            self.registry.default_model = Some(d.to_string());
        }
        if a.get("preload-models").is_some() {
            self.registry.preload = a.get_bool("preload-models");
        }
        Ok(())
    }

    /// Build from CLI: `--config` file first, then flag overrides.
    pub fn from_args(a: &Args) -> Result<Config> {
        let mut c = match a.get("config") {
            Some(p) => Config::from_file(Path::new(p))?,
            None => Config::default(),
        };
        c.apply_args(a)?;
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.replica_cache_mb == 0 {
            bail!("replica_cache_mb must be >= 1");
        }
        if !self.prefetch_threshold.is_finite() || self.prefetch_threshold < 0.0 {
            bail!(
                "prefetch_threshold must be finite and >= 0 (req/s; 0 disables), got {}",
                self.prefetch_threshold
            );
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.queue_capacity < self.max_batch {
            bail!(
                "queue_capacity ({}) must be >= max_batch ({})",
                self.queue_capacity,
                self.max_batch
            );
        }
        if self.batch_timeout > Duration::from_secs(10) {
            bail!("batch_timeout > 10s is almost certainly a unit mistake");
        }
        if !(self.policy.ewma_alpha > 0.0 && self.policy.ewma_alpha <= 1.0) {
            bail!(
                "ewma_alpha must be in (0, 1], got {}",
                self.policy.ewma_alpha
            );
        }
        if self.policy.margin < 1.0 {
            bail!("margin must be >= 1.0, got {}", self.policy.margin);
        }
        if self.pool.per_class_cap == 0 {
            bail!("pool per_class_cap must be >= 1 (use pool.enabled=false to disable)");
        }
        if self.server.io_threads == 0 {
            bail!("io_threads must be >= 1");
        }
        if self.server.max_connections == 0 {
            bail!("max_connections must be >= 1");
        }
        // A budget below one small JSON request can't carry the
        // protocol; it's a unit mistake, not a tighter bound.
        if self.server.max_line_bytes < 256 {
            bail!(
                "max_line_bytes must be >= 256, got {}",
                self.server.max_line_bytes
            );
        }
        // Below one 1x1 RGB pixel nothing can ship; tests legitimately
        // use small budgets to exercise oversize rejects.
        if self.server.max_frame_bytes < 3 {
            bail!(
                "max_frame_bytes must be >= 3, got {}",
                self.server.max_frame_bytes
            );
        }
        if !(0.0..=1.0).contains(&self.obs.trace_sample_rate) {
            bail!(
                "trace_sample_rate must be in [0, 1], got {}",
                self.obs.trace_sample_rate
            );
        }
        if self.obs.trace_ring == 0 {
            bail!("trace_ring must be >= 1 (use trace_sample_rate 0 to disable)");
        }
        if self.obs.slow_log == 0 {
            bail!("slow_log must be >= 1");
        }
        if self.policy.adaptive {
            if self.policy.quant_workers == 0 {
                bail!("quant_workers must be >= 1 when adaptive");
            }
            if self.engine == EngineKind::Quant {
                bail!(
                    "adaptive mode pairs the configured engine with the \
                     quant pool; --engine quant is redundant (pick acl/tf)"
                );
            }
        }
        // Registry: names must be non-empty and the default must exist.
        for (name, _) in &self.registry.models {
            if name.is_empty() {
                bail!("registry model names must be non-empty");
            }
        }
        // Scheduler weights: positive, finite, and addressed at a
        // registered model (a typo'd weight silently weighing nothing
        // would defeat the operator's intent).
        for (name, w) in &self.registry.weights {
            if !w.is_finite() || *w <= 0.0 {
                bail!("model weight for '{name}' must be finite and > 0, got {w}");
            }
            let known = self.registry.models.iter().any(|(n, _)| n == name)
                || (self.registry.models.is_empty()
                    && name == RegistryConfig::SINGLE_MODEL);
            if !known {
                bail!("model weight for '{name}': no such registered model");
            }
        }
        if let Some(d) = &self.registry.default_model {
            let known = self.registry.models.iter().any(|(n, _)| n == d);
            // In single-model mode only the implicit name is addressable.
            let single_ok = self.registry.models.is_empty()
                && d == RegistryConfig::SINGLE_MODEL;
            if !known && !single_ok {
                bail!(
                    "default model '{d}' is not among the registered models \
                     ({:?})",
                    self.registry
                        .models
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                );
            }
        } else if self.registry.models.len() > 1 {
            // JSON objects don't preserve declaration order (the parser
            // is a BTreeMap), so "first registered" would silently mean
            // "alphabetically first" for models.json users.  Make
            // multi-model deployments say which model is the default.
            bail!(
                "a registry with {} models needs an explicit default \
                 (\"default\" in models.json / --default-model)",
                self.registry.models.len()
            );
        }
        Ok(())
    }

    /// CLI flags this config understands (for Args::parse `known` lists).
    pub const FLAGS: &'static [&'static str] = &[
        "config",
        "artifacts",
        "engine",
        "workers",
        "runtime-workers",
        "replica-cache-mb",
        "max-batch",
        "batch-timeout-ms",
        "queue-capacity",
        "listen",
        "log-level",
        "adaptive",
        "quant-workers",
        "cache-capacity",
        "ewma-alpha",
        "margin",
        "pool",
        "pool-cap",
        "model",
        "models",
        "model-weight",
        "default-model",
        "preload-models",
        "conn-plane",
        "io-threads",
        "max-connections",
        "max-line-bytes",
        "max-frame-bytes",
        "idle-timeout-ms",
        "wire-parser",
        "trace-sample-rate",
        "trace-ring",
        "slow-log",
        "snapshots",
        "prefetch-threshold",
        "compat-error-alias",
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{"engine":"tf","workers":2,"max_batch":4,
                "batch_timeout_ms":5.5,"queue_capacity":32,
                "listen":"0.0.0.0:9000"}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.engine, EngineKind::TfBaseline);
        assert_eq!(c.workers, 2);
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.batch_timeout, Duration::from_micros(5500));
        assert_eq!(c.listen, "0.0.0.0:9000");
        c.validate().unwrap();
    }

    #[test]
    fn cli_overrides_beat_defaults() {
        let a = Args::parse(
            ["serve", "--engine", "acl-fused", "--max-batch", "2"]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.engine, EngineKind::AclFused);
        assert_eq!(c.max_batch, 2);
    }

    #[test]
    fn policy_knobs_from_json_and_cli() {
        let j = Json::parse(
            r#"{"policy":{"adaptive":true,"quant_workers":2,
                "cache_capacity":64,"ewma_alpha":0.5,"margin":1.5}}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert!(c.policy.adaptive);
        assert_eq!(c.policy.quant_workers, 2);
        assert_eq!(c.policy.cache_capacity, 64);
        assert_eq!(c.policy.ewma_alpha, 0.5);
        assert_eq!(c.policy.margin, 1.5);
        c.validate().unwrap();

        let a = Args::parse(
            ["serve", "--adaptive", "--cache-capacity", "16"]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert!(c.policy.adaptive);
        assert_eq!(c.policy.cache_capacity, 16);
    }

    #[test]
    fn policy_validation() {
        let mut c = Config::default();
        c.policy.ewma_alpha = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.policy.margin = 0.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.policy.adaptive = true;
        c.engine = EngineKind::Quant;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pool_knobs_from_json_and_cli() {
        let j = Json::parse(r#"{"pool":{"enabled":false,"per_class_cap":4}}"#).unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert!(!c.pool.enabled);
        assert_eq!(c.pool.per_class_cap, 4);
        c.validate().unwrap();

        let a = Args::parse(
            ["serve", "--pool", "false", "--pool-cap", "8"]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert!(!c.pool.enabled);
        assert_eq!(c.pool.per_class_cap, 8);

        // Typos must error, not silently flip into ablation mode.
        let bad = Args::parse(
            ["serve", "--pool", "ture"].iter().map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());

        let mut c = Config::default();
        c.pool.per_class_cap = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn registry_knobs_from_json_and_cli() {
        let j = Json::parse(
            r#"{"registry":{"default":"b","preload":true,
                "models":{"a":"/m/a","b":"/m/b"}}}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.registry.models.len(), 2);
        assert_eq!(c.registry.effective_default(), "b");
        assert!(c.registry.preload);
        c.validate().unwrap();

        // Repeated --model flags register in order; later same-name
        // flags override; --default-model picks the default.
        let a = Args::parse(
            [
                "serve",
                "--model",
                "a=/m/a",
                "--model",
                "b=/m/b",
                "--model",
                "a=/m/a2",
                "--default-model",
                "a",
            ]
            .iter()
            .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.registry.models.len(), 2);
        assert_eq!(c.registry.models[0], ("a".to_string(), "/m/a2".into()));
        assert_eq!(c.registry.effective_default(), "a");

        // Malformed --model specs fail loudly.
        for bad in ["ab", "=path", "name="] {
            let a = Args::parse(
                ["serve", "--model", bad].iter().map(|s| s.to_string()),
                Config::FLAGS,
            )
            .unwrap();
            assert!(Config::from_args(&a).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn multi_model_registry_requires_explicit_default() {
        // JSON objects don't preserve order, so "first wins" would be
        // "alphabetical wins" for models.json users — refuse to guess.
        let mut c = Config::default();
        c.registry.upsert("b", "/m/b".into());
        c.registry.upsert("a", "/m/a".into());
        assert!(c.validate().is_err());
        c.registry.default_model = Some("b".to_string());
        c.validate().unwrap();
        // One model needs no explicit default.
        let mut c = Config::default();
        c.registry.upsert("only", "/m/only".into());
        c.validate().unwrap();
        assert_eq!(c.registry.effective_default(), "only");
    }

    #[test]
    fn registry_default_must_be_registered() {
        let mut c = Config::default();
        c.registry.upsert("a", "/m/a".into());
        c.registry.default_model = Some("nope".to_string());
        assert!(c.validate().is_err());
        c.registry.default_model = Some("a".to_string());
        c.validate().unwrap();
        // Single-model mode: only the implicit name is addressable.
        let mut c = Config::default();
        c.registry.default_model = Some("custom".to_string());
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.registry.default_model =
            Some(RegistryConfig::SINGLE_MODEL.to_string());
        c.validate().unwrap();
    }

    #[test]
    fn models_index_loads_with_relative_paths() {
        let dir = std::env::temp_dir()
            .join(format!("zuluko_cfg_index_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let idx = dir.join("models.json");
        std::fs::write(
            &idx,
            r#"{"default":"x","models":{"x":"artifacts-x","y":"/abs/y"}}"#,
        )
        .unwrap();
        let reg = RegistryConfig::load_index(&idx).unwrap();
        assert_eq!(reg.default_model.as_deref(), Some("x"));
        let x = reg.models.iter().find(|(n, _)| n == "x").unwrap();
        assert_eq!(x.1, dir.join("artifacts-x"));
        let y = reg.models.iter().find(|(n, _)| n == "y").unwrap();
        assert_eq!(y.1, PathBuf::from("/abs/y"));
        // An index without a "models" object is an error, not an empty
        // registry.
        std::fs::write(&idx, r#"{"default":"x"}"#).unwrap();
        assert!(RegistryConfig::load_index(&idx).is_err());
    }

    #[test]
    fn workers_default_to_core_count() {
        let c = Config::default();
        assert_eq!(c.workers, crate::metrics::sysmon::num_cpus().max(1));
        assert!(c.workers >= 1);
    }

    #[test]
    fn runtime_knobs_from_json_and_cli() {
        let j = Json::parse(r#"{"workers":2,"runtime_workers":3,"replica_cache_mb":64}"#)
            .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        // runtime_workers is the explicit alias and wins.
        assert_eq!(c.workers, 3);
        assert_eq!(c.replica_cache_mb, 64);
        c.validate().unwrap();

        let a = Args::parse(
            ["serve", "--runtime-workers", "5", "--replica-cache-mb", "32"]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.workers, 5);
        assert_eq!(c.replica_cache_mb, 32);

        let mut c = Config::default();
        c.replica_cache_mb = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn model_weights_from_json_cli_and_index() {
        let j = Json::parse(
            r#"{"registry":{"default":"a","models":{"a":"/m/a","b":"/m/b"},
                "weights":{"a":3.0}}}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.registry.weight_for("a"), 3.0);
        assert_eq!(c.registry.weight_for("b"), 1.0, "absent weight defaults to 1");
        c.validate().unwrap();

        let a = Args::parse(
            [
                "serve",
                "--model",
                "a=/m/a",
                "--model-weight",
                "a=2.5",
            ]
            .iter()
            .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.registry.weight_for("a"), 2.5);

        // models.json index carries weights too.
        let dir = std::env::temp_dir()
            .join(format!("zuluko_cfg_weights_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let idx = dir.join("models.json");
        std::fs::write(
            &idx,
            r#"{"default":"x","models":{"x":"ax","y":"ay"},"weights":{"y":0.5}}"#,
        )
        .unwrap();
        let reg = RegistryConfig::load_index(&idx).unwrap();
        assert_eq!(reg.weight_for("y"), 0.5);
        assert_eq!(reg.weight_for("x"), 1.0);

        // ...and the `--models index.json` CLI path must carry them
        // through to the effective config, not just parse them.
        let a = Args::parse(
            ["serve", "--models", idx.to_str().unwrap()]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.registry.weight_for("y"), 0.5, "--models dropped weights");
        assert_eq!(c.registry.weight_for("x"), 1.0);
    }

    #[test]
    fn model_weight_validation_rejects_nonsense() {
        // Non-positive / non-finite weights fail.
        let mut c = Config::default();
        c.registry.upsert("a", "/m/a".into());
        c.registry.set_weight("a", 0.0);
        assert!(c.validate().is_err());
        c.registry.set_weight("a", f64::NAN);
        assert!(c.validate().is_err());
        c.registry.set_weight("a", 2.0);
        c.validate().unwrap();
        // A weight for an unregistered model is an error, not a no-op.
        c.registry.set_weight("ghost", 1.5);
        assert!(c.validate().is_err());
        // Single-model mode: only the implicit name is weightable.
        let mut c = Config::default();
        c.registry.set_weight(RegistryConfig::SINGLE_MODEL, 2.0);
        c.validate().unwrap();
        let mut c = Config::default();
        c.registry.set_weight("other", 2.0);
        assert!(c.validate().is_err());
        // Malformed --model-weight specs fail loudly.
        for bad in ["a", "=2", "a=", "a=fast"] {
            let a = Args::parse(
                ["serve", "--model-weight", bad].iter().map(|s| s.to_string()),
                Config::FLAGS,
            )
            .unwrap();
            assert!(Config::from_args(&a).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn server_knobs_from_json_and_cli() {
        let j = Json::parse(
            r#"{"server":{"conn_plane":"threads","io_threads":4,
                "max_connections":5000,"max_line_bytes":4096,
                "max_frame_bytes":65536,
                "idle_timeout_ms":0,"wire_parser":"tree"}}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.server.conn_plane, ConnPlane::Threads);
        assert_eq!(c.server.io_threads, 4);
        assert_eq!(c.server.max_connections, 5000);
        assert_eq!(c.server.max_line_bytes, 4096);
        assert_eq!(c.server.max_frame_bytes, 65536);
        assert_eq!(c.server.idle_timeout_ms, 0);
        assert_eq!(c.server.wire_parser, WireParser::Tree);
        c.validate().unwrap();

        let a = Args::parse(
            [
                "serve",
                "--conn-plane",
                "event",
                "--wire-parser",
                "tape",
                "--io-threads",
                "3",
                "--max-connections",
                "2000",
                "--max-line-bytes",
                "512",
                "--max-frame-bytes",
                "4096",
                "--idle-timeout-ms",
                "30000",
            ]
            .iter()
            .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.server.conn_plane, ConnPlane::Event);
        assert_eq!(c.server.wire_parser, WireParser::Tape);
        assert_eq!(c.server.io_threads, 3);
        assert_eq!(c.server.max_connections, 2000);
        assert_eq!(c.server.max_line_bytes, 512);
        assert_eq!(c.server.max_frame_bytes, 4096);
        assert_eq!(c.server.idle_timeout_ms, 30_000);

        // A typo'd plane must error, never silently fall back.
        let bad = Args::parse(
            ["serve", "--conn-plane", "evnt"].iter().map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        let bad = Args::parse(
            ["serve", "--wire-parser", "tap"].iter().map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
    }

    #[test]
    fn server_validation_rejects_nonsense() {
        let mut c = Config::default();
        c.server.io_threads = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.server.max_connections = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.server.max_line_bytes = 64;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.server.max_frame_bytes = 2;
        assert!(c.validate().is_err());
        // idle_timeout_ms 0 is valid: it disables eviction.
        let mut c = Config::default();
        c.server.idle_timeout_ms = 0;
        c.validate().unwrap();
    }

    #[test]
    fn obs_knobs_from_json_and_cli() {
        let j = Json::parse(
            r#"{"obs":{"trace_sample_rate":0.5,"trace_ring":64,"slow_log":16}}"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.obs.trace_sample_rate, 0.5);
        assert_eq!(c.obs.trace_ring, 64);
        assert_eq!(c.obs.slow_log, 16);
        c.validate().unwrap();

        let a = Args::parse(
            [
                "serve",
                "--trace-sample-rate",
                "0",
                "--trace-ring",
                "32",
                "--slow-log",
                "8",
            ]
            .iter()
            .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.obs.trace_sample_rate, 0.0);
        assert_eq!(c.obs.trace_ring, 32);
        assert_eq!(c.obs.slow_log, 8);

        // Rates outside [0, 1] and zero-capacity rings fail validation.
        let mut c = Config::default();
        c.obs.trace_sample_rate = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.obs.trace_sample_rate = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.obs.trace_ring = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.obs.slow_log = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn snapshot_knobs_from_json_and_cli() {
        let j = Json::parse(r#"{"snapshots":"refresh","prefetch_threshold":2.5}"#)
            .unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.snapshots, SnapshotMode::Refresh);
        assert_eq!(c.prefetch_threshold, 2.5);
        c.validate().unwrap();

        let a = Args::parse(
            ["serve", "--snapshots", "off", "--prefetch-threshold", "1.5"]
                .iter()
                .map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.snapshots, SnapshotMode::Off);
        assert_eq!(c.prefetch_threshold, 1.5);

        // Typos must error, never silently fall back to cold builds.
        let bad = Args::parse(
            ["serve", "--snapshots", "onn"].iter().map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());

        let mut c = Config::default();
        c.prefetch_threshold = -1.0;
        assert!(c.validate().is_err());
        c.prefetch_threshold = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn snapshot_mode_parses_and_displays() {
        assert_eq!(SnapshotMode::parse("on").unwrap(), SnapshotMode::On);
        assert_eq!(SnapshotMode::parse("off").unwrap(), SnapshotMode::Off);
        assert_eq!(SnapshotMode::parse("refresh").unwrap(), SnapshotMode::Refresh);
        assert!(SnapshotMode::parse("never").is_err());
        assert_eq!(SnapshotMode::default(), SnapshotMode::On);
        assert_eq!(SnapshotMode::On.to_string(), "on");
        assert!(SnapshotMode::On.reads() && SnapshotMode::On.writes());
        assert!(!SnapshotMode::Off.reads() && !SnapshotMode::Off.writes());
        assert!(!SnapshotMode::Refresh.reads() && SnapshotMode::Refresh.writes());
    }

    #[test]
    fn compat_error_alias_from_json_and_cli() {
        assert!(!ServerConfig::default().compat_error_alias);
        let j = Json::parse(r#"{"server":{"compat_error_alias":true}}"#).unwrap();
        let mut c = Config::default();
        c.apply_json(&j).unwrap();
        assert!(c.server.compat_error_alias);

        let a = Args::parse(
            ["serve", "--compat-error-alias"].iter().map(|s| s.to_string()),
            Config::FLAGS,
        )
        .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert!(c.server.compat_error_alias);
    }

    #[test]
    fn conn_plane_parses_and_displays() {
        assert_eq!(ConnPlane::parse("event").unwrap(), ConnPlane::Event);
        assert_eq!(ConnPlane::parse("threads").unwrap(), ConnPlane::Threads);
        assert!(ConnPlane::parse("epoll").is_err());
        assert_eq!(ConnPlane::Event.to_string(), "event");
        assert_eq!(ConnPlane::Threads.to_string(), "threads");
        assert_eq!(ConnPlane::default(), ConnPlane::Event);
    }

    #[test]
    fn wire_parser_parses_and_displays() {
        assert_eq!(WireParser::parse("tape").unwrap(), WireParser::Tape);
        assert_eq!(WireParser::parse("tree").unwrap(), WireParser::Tree);
        assert!(WireParser::parse("taep").is_err());
        assert_eq!(WireParser::Tape.to_string(), "tape");
        assert_eq!(WireParser::Tree.to_string(), "tree");
        assert_eq!(WireParser::default(), WireParser::Tape);
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = Config::default();
        c.workers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.queue_capacity = 1;
        c.max_batch = 8;
        assert!(c.validate().is_err());
    }
}
