//! # zuluko-infer
//!
//! A from-scratch embedded inference **serving engine**, reproducing
//! *"Enabling Embedded Inference Engine with the ARM Compute Library: A
//! Case Study"* (Sun, Liu, Gaudiot 2017) on a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the "ACL
//!   building blocks" (conv, pool, softmax, the fused concat-free fire
//!   module, int8 quantization).
//! * **L2** — JAX SqueezeNet v1.0 (`python/compile/model.py`), AOT-lowered
//!   to HLO-text artifacts.
//! * **L3** — this crate: the serving coordinator (a shared worker
//!   runtime — fixed thread fleet over a weighted-fair scheduler of all
//!   (model, engine) queues — dynamic batcher, TCP server) with two
//!   execution backends:
//!   the paper's from-scratch **ACL engine** (fused stages) and the
//!   **TF-baseline engine** (op-by-op graph interpreter), plus the Fig 4
//!   quantized variant — topped by an SLO-aware **policy layer**
//!   (`policy`): per-request deadlines/priorities, an online latency
//!   predictor, adaptive engine selection with load shedding, and a
//!   content-addressed response cache.
//!
//! Python never runs on the request path; `make artifacts` runs it once.
//!
//! See DESIGN.md for the full system inventory, the experiment index,
//! and the substitution rationale (§Substitutions).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod policy;
pub mod registry;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod testkit;
pub mod trace;
pub mod util;

use std::path::PathBuf;

/// Locate the artifacts directory: `$ZULUKO_ARTIFACTS` or `./artifacts`
/// (walking up from the current dir so tests work from target/).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("ZULUKO_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
