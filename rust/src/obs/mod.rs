//! Observability spine: request-lifecycle tracing + unified metrics
//! (DESIGN.md §10).
//!
//! Every request carries a small, `Copy` [`Span`] — eight fixed stage
//! marks (`accepted` → `reply_flushed`) stamped with monotonic ticks as
//! the request crosses the connection plane, admission, the scheduler,
//! the engine, and the completion sink.  The span travels *inside* the
//! request (and back inside the response), so stamping is a relaxed
//! store into an inline array: no mutex, no allocation, no global map.
//!
//! Retention is split in two:
//!
//! * **Head sampling** (`--trace-sample-rate`): one in N spans is
//!   marked `sampled` at accept time; on completion a sampled span is
//!   recorded into one of a fixed set of lock-free [`TraceRing`]s
//!   (per-IO-lane on the event plane, id-hashed on the threads plane
//!   and for library callers).  The rings are single-word-atomic
//!   seqlock buffers: writers never block, never allocate, and a
//!   reader (`{"cmd":"trace"}`) that races a writer simply skips the
//!   torn slot — traces are diagnostics, best-effort by design.
//! * **Always-capture for anomalies**: a request that is shed
//!   (predicted or expired), misses its deadline, or lands in the
//!   slowest tail (coarse online p99.9 estimate) is pushed into a
//!   bounded slow log with its full stage breakdown regardless of the
//!   sample decision — the requests worth debugging are exactly the
//!   ones sampling would usually drop.
//!
//! Per-stage latency *distributions* are kept separately in
//! [`StageHist`] (one per model generation, merged across models via
//! [`Histogram::merge`] for the unified `{"cmd":"metrics"}` export);
//! those are recorded once per batch under a short lock, off the
//! per-request path.
//!
//! Overhead budget (enforced by `rust/benches/trace_overhead.rs`): the
//! default sample rate must cost ≤5% p99 and ≤5% allocations/request
//! against tracing compiled in but sampled out.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::Histogram;

/// Number of lifecycle stages in a [`Span`].
pub const STAGES: usize = 8;

/// Stage names in mark order (wire names for `{"cmd":"trace"}`).
pub const STAGE_NAMES: [&str; STAGES] = [
    "accepted",
    "parsed",
    "admitted",
    "dequeued",
    "batch_formed",
    "infer_start",
    "infer_done",
    "reply_flushed",
];

/// Fixed request-lifecycle stages, in the order they are stamped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request line received from the socket.
    Accepted = 0,
    /// Request line parsed into a protocol message.
    Parsed = 1,
    /// Admitted into a scheduler queue (selector routed, queue accepted).
    Admitted = 2,
    /// Popped from the queue by a runtime worker.
    Dequeued = 3,
    /// Batch assembled (post-shed, post-split, pixels copied in place).
    BatchFormed = 4,
    /// Engine `infer_view` entered.
    InferStart = 5,
    /// Engine `infer_view` returned.
    InferDone = 6,
    /// Reply bytes handed to the connection (write buffer flushed).
    ReplyFlushed = 7,
}

/// Span flag bits (`Span::flags`).
pub mod flag {
    /// Head-sampled at accept time (recorded into a trace ring).
    pub const SAMPLED: u64 = 1;
    /// Shed at admission: no engine predicted to meet the deadline.
    pub const SHED_PREDICTED: u64 = 1 << 1;
    /// Admitted but shed in-queue after the deadline passed.
    pub const SHED_EXPIRED: u64 = 1 << 2;
    /// Served, but the reply landed after the deadline budget.
    pub const DEADLINE_MISSED: u64 = 1 << 3;
    /// Landed in the slowest tail (online p99.9 estimate).
    pub const SLOW: u64 = 1 << 4;
    /// Answered from the response cache (no engine stages).
    pub const CACHE_HIT: u64 = 1 << 5;
    /// Structurally rejected (queue full / closed) after routing.
    pub const REJECTED: u64 = 1 << 6;
}

/// Human-readable names for set flag bits, in bit order.
pub fn flag_names(flags: u64) -> Vec<&'static str> {
    const TABLE: [(u64, &str); 7] = [
        (flag::SAMPLED, "sampled"),
        (flag::SHED_PREDICTED, "shed_predicted"),
        (flag::SHED_EXPIRED, "shed_expired"),
        (flag::DEADLINE_MISSED, "deadline_missed"),
        (flag::SLOW, "slow"),
        (flag::CACHE_HIT, "cache_hit"),
        (flag::REJECTED, "rejected"),
    ];
    TABLE
        .iter()
        .filter(|(bit, _)| flags & bit != 0)
        .map(|&(_, name)| name)
        .collect()
}

/// One request's lifecycle timeline: eight monotonic marks (nanoseconds
/// since the hub epoch; 0 = stage not reached), the deadline budget,
/// and classification flags.  `Copy` and mutex-free on purpose: it
/// rides inside the [`crate::coordinator::Request`] and back inside the
/// [`crate::coordinator::Response`], so stamping a stage is one inline
/// store.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Span {
    /// Coordinator-internal request id (0 until submit assigns one).
    pub id: u64,
    /// Per-stage monotonic marks, ns since the hub epoch; 0 = unset.
    pub marks: [u64; STAGES],
    /// Deadline budget in ns (0 = best-effort), measured from admission.
    pub deadline_ns: u64,
    pub flags: u64,
}

impl Span {
    /// Stamp `stage` at tick `now_ns` (from [`ObsHub::now_ns`]).
    #[inline]
    pub fn set(&mut self, stage: Stage, now_ns: u64) {
        self.marks[stage as usize] = now_ns;
    }

    /// The mark for `stage`, if that stage was reached.
    pub fn get(&self, stage: Stage) -> Option<u64> {
        let v = self.marks[stage as usize];
        (v != 0).then_some(v)
    }

    pub fn sampled(&self) -> bool {
        self.flags & flag::SAMPLED != 0
    }

    /// Earliest set mark (the span's start), 0 if none.
    pub fn first_ns(&self) -> u64 {
        self.marks.iter().copied().filter(|&m| m != 0).min().unwrap_or(0)
    }

    /// Latest set mark (the span's end), 0 if none.
    pub fn last_ns(&self) -> u64 {
        self.marks.iter().copied().max().unwrap_or(0)
    }

    /// End-to-end wall time across set marks, in ms.
    pub fn total_ms(&self) -> f64 {
        self.last_ns().saturating_sub(self.first_ns()) as f64 / 1e6
    }

    /// Latency basis for deadline accounting: the admission mark when
    /// reached (deadlines are measured from submit), else the earliest
    /// mark.
    fn deadline_basis_ns(&self) -> u64 {
        self.get(Stage::Admitted).unwrap_or_else(|| self.first_ns())
    }

    /// True when every set mark is ≥ the previous set mark — the
    /// invariant `{"cmd":"trace"}` consumers rely on.
    pub fn monotonic(&self) -> bool {
        let mut prev = 0u64;
        for &m in &self.marks {
            if m == 0 {
                continue;
            }
            if m < prev {
                return false;
            }
            prev = m;
        }
        true
    }
}

// ---------------------------------------------------------------------------
// Lock-free trace ring
// ---------------------------------------------------------------------------

/// Words per ring slot: id + marks + deadline + flags.
const SPAN_WORDS: usize = 2 + STAGES + 1;

struct Slot {
    /// Seqlock version: `2·ticket+1` while a write is in progress,
    /// `2·ticket+2` once slot holds ticket's span.  A reader that sees
    /// an odd or changed version skips the slot.
    ver: AtomicU64,
    words: [AtomicU64; SPAN_WORDS],
}

/// A fixed-capacity, lock-free span ring (multi-writer seqlock).
///
/// * `push` never blocks and never allocates: one `fetch_add` claims a
///   ticket, the slot is overwritten in place.
/// * Readers ([`TraceRing::snapshot`]) are best-effort: a slot being
///   overwritten concurrently is detected via its version and skipped,
///   never returned torn.
/// * The ring never exceeds its capacity — older spans are simply
///   overwritten (property-tested in rust/tests/obs_props.rs).
pub struct TraceRing {
    slots: Box<[Slot]>,
    next: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(1);
        let slots: Vec<Slot> = (0..capacity)
            .map(|_| Slot {
                ver: AtomicU64::new(0),
                words: std::array::from_fn(|_| AtomicU64::new(0)),
            })
            .collect();
        TraceRing {
            slots: slots.into_boxed_slice(),
            next: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        (self.next.load(Ordering::Acquire) as usize).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.next.load(Ordering::Acquire) == 0
    }

    /// Record a span.  Never blocks: one ticket `fetch_add`, one claim
    /// CAS, then plain stores.  A same-slot lap collision (two writers
    /// whose tickets are a full capacity apart, racing) makes the loser
    /// *drop* its span instead of interleaving words into the slot — a
    /// trace ring favors consistency over completeness, and the lapped
    /// span was about to be overwritten anyway.
    pub fn push(&self, s: &Span) {
        let ticket = self.next.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        // Claim the slot even→odd.  An odd version means a contemporary
        // writer holds it; a newer version means this ticket was lapped
        // while parked.  Either way, never write words we don't own.
        let claim = 2 * ticket + 1;
        let cur = slot.ver.load(Ordering::Acquire);
        if cur % 2 == 1
            || cur > claim
            || slot
                .ver
                .compare_exchange(cur, claim, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
        {
            return;
        }
        // Canonical seqlock writer fence: the word stores below must not
        // become visible before the odd version above, or a reader could
        // consume a half-written slot as consistent.
        std::sync::atomic::fence(Ordering::Release);
        slot.words[0].store(s.id, Ordering::Relaxed);
        for (i, m) in s.marks.iter().enumerate() {
            slot.words[1 + i].store(*m, Ordering::Relaxed);
        }
        slot.words[1 + STAGES].store(s.deadline_ns, Ordering::Relaxed);
        slot.words[2 + STAGES].store(s.flags, Ordering::Relaxed);
        slot.ver.store(2 * ticket + 2, Ordering::Release);
    }

    /// Newest-first snapshot of up to `k` retained spans.  Slots being
    /// overwritten while read are skipped (seqlock check), so a
    /// snapshot under write load can return fewer than `len()` spans —
    /// never a torn one.
    pub fn snapshot(&self, k: usize) -> Vec<Span> {
        let newest = self.next.load(Ordering::Acquire);
        let retained = newest.min(self.slots.len() as u64);
        let mut out = Vec::with_capacity(retained.min(k as u64) as usize);
        let mut ticket = newest;
        while ticket > newest - retained && out.len() < k {
            ticket -= 1;
            let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
            let want = 2 * ticket + 2;
            if slot.ver.load(Ordering::Acquire) != want {
                continue; // mid-write, or lapped by a newer span
            }
            let mut s = Span {
                id: slot.words[0].load(Ordering::Relaxed),
                ..Span::default()
            };
            for (i, m) in s.marks.iter_mut().enumerate() {
                *m = slot.words[1 + i].load(Ordering::Relaxed);
            }
            s.deadline_ns = slot.words[1 + STAGES].load(Ordering::Relaxed);
            s.flags = slot.words[2 + STAGES].load(Ordering::Relaxed);
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.ver.load(Ordering::Acquire) == want {
                out.push(s);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Per-model stage histograms
// ---------------------------------------------------------------------------

/// One exported per-stage latency row (`{"cmd":"metrics"}`).
#[derive(Debug, Clone)]
pub struct StageRow {
    pub stage: &'static str,
    pub count: u64,
    /// (mean, p50, p95, p99, max) in ms.
    pub summary: (f64, f64, f64, f64, f64),
}

/// Per-stage duration histograms for one model generation: index `i`
/// holds the duration *ending* at stage `i` (from the previous reached
/// stage), so `stages[InferDone]` is engine wall time and
/// `stages[Dequeued]` is queue wait.  Recorded once per batch under a
/// short lock (off the per-request hot path), merged across models via
/// [`Histogram::merge`] for the unified metrics export.
pub struct StageHist {
    inner: Mutex<Vec<Histogram>>,
}

impl Default for StageHist {
    fn default() -> Self {
        StageHist::new()
    }
}

impl StageHist {
    pub fn new() -> StageHist {
        StageHist {
            // Bounded retention per stage: metrics snapshots are summaries,
            // not sample dumps.
            inner: Mutex::new((0..STAGES).map(|_| Histogram::with_cap(4096)).collect()),
        }
    }

    /// Record every stage-to-stage duration present in `spans`.  One
    /// lock for the whole batch.
    pub fn record_batch(&self, spans: impl Iterator<Item = Span>) {
        let mut h = self.inner.lock().unwrap();
        for span in spans {
            let mut prev = 0u64;
            for (i, &m) in span.marks.iter().enumerate() {
                if m == 0 {
                    continue;
                }
                if prev != 0 {
                    h[i].record_ms(m.saturating_sub(prev) as f64 / 1e6);
                }
                prev = m;
            }
        }
    }

    /// Clone the per-stage histograms (for merging across models).
    pub fn histograms(&self) -> Vec<Histogram> {
        self.inner.lock().unwrap().clone()
    }

    /// Summary rows for stages that saw any samples, skipping
    /// `accepted` (a point, not a duration).
    pub fn rows(&self) -> Vec<StageRow> {
        rows_of(&self.inner.lock().unwrap())
    }
}

/// Summary rows from a per-stage histogram slice (shared by per-model
/// and merged-global exports).  Stage 0 (`accepted`) is a point in
/// time, not a duration, and is always skipped.
pub fn rows_of(hists: &[Histogram]) -> Vec<StageRow> {
    hists
        .iter()
        .enumerate()
        .skip(1)
        .filter(|(_, h)| h.count() > 0)
        .map(|(i, h)| StageRow {
            stage: STAGE_NAMES[i],
            count: h.count(),
            summary: h.summary(),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// The hub
// ---------------------------------------------------------------------------

/// Counter snapshot for the `trace` section of `{"cmd":"metrics"}`.
#[derive(Debug, Clone, Default)]
pub struct ObsCounters {
    /// Spans begun (one per inference request seen by a server plane or
    /// library submit).
    pub begun: u64,
    /// Spans completed through [`ObsHub::complete`].
    pub completed: u64,
    /// Sampled spans recorded into trace rings.
    pub recorded: u64,
    /// Completed spans dropped by head sampling (zero residue).
    pub sampled_out: u64,
    /// Anomalies retained in the slow log (shed / deadline-missed /
    /// slowest-tail).
    pub anomalies: u64,
    /// Effective head-sampling period (0 = never, 1 = every request).
    pub sample_period: u64,
    pub rings: usize,
    pub ring_capacity: usize,
    pub slow_capacity: usize,
    /// Online p99.9 latency estimate used for slow-tail capture, ms.
    pub p999_est_ms: f64,
    /// Reply-flush segment (infer_done → reply_flushed) count/mean/max
    /// ms — kept as atomics because completion runs on IO threads.
    pub flush_count: u64,
    pub flush_mean_ms: f64,
    pub flush_max_ms: f64,
}

/// Completions before the slow-tail (p99.9) capture arms — the
/// estimator needs a population before "slowest 0.1%" means anything.
const SLOW_WARMUP: u64 = 512;

/// Process-wide tracing hub: the monotonic clock epoch, the sampling
/// decision, the trace rings, and the anomaly slow log.  Owned by the
/// coordinator's `SharedStats` so the server planes, the admission
/// path, and the runtime workers all stamp against the same epoch.
pub struct ObsHub {
    epoch: Instant,
    /// Head-sampling period: 0 = never, 1 = always, N = one in N.
    period: u64,
    sample_counter: AtomicU64,
    rings: Box<[TraceRing]>,
    slow: TraceRing,
    /// Coarse online p99.9 estimate (ns) for slow-tail capture.
    p999_ns: AtomicU64,
    begun: AtomicU64,
    completed: AtomicU64,
    recorded: AtomicU64,
    sampled_out: AtomicU64,
    anomalies: AtomicU64,
    flush_count: AtomicU64,
    flush_sum_ns: AtomicU64,
    flush_max_ns: AtomicU64,
}

impl Default for ObsHub {
    /// Library default: 1-in-100 sampling, 4 rings × 1024 spans,
    /// 256-slot slow log (the config-driven constructor is
    /// [`ObsHub::new`]).
    fn default() -> Self {
        ObsHub::new(0.01, 1024, 256, 4)
    }
}

impl ObsHub {
    pub fn new(sample_rate: f64, ring_cap: usize, slow_cap: usize, rings: usize) -> ObsHub {
        let period = if sample_rate.is_nan() || sample_rate <= 0.0 {
            0 // NaN or ≤0: tracing compiled in, sampled out
        } else if sample_rate >= 1.0 {
            1
        } else {
            (1.0 / sample_rate).round() as u64
        };
        let rings: Vec<TraceRing> = (0..rings.max(1)).map(|_| TraceRing::new(ring_cap)).collect();
        ObsHub {
            epoch: Instant::now(),
            period,
            sample_counter: AtomicU64::new(0),
            rings: rings.into_boxed_slice(),
            slow: TraceRing::new(slow_cap),
            p999_ns: AtomicU64::new(0),
            begun: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
            anomalies: AtomicU64::new(0),
            flush_count: AtomicU64::new(0),
            flush_sum_ns: AtomicU64::new(0),
            flush_max_ns: AtomicU64::new(0),
        }
    }

    /// Monotonic tick: ns since the hub epoch, never 0 (0 is the
    /// "stage not reached" sentinel in [`Span::marks`]).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        (self.epoch.elapsed().as_nanos() as u64).max(1)
    }

    /// Begin a span now (stamps `accepted`, draws the sample decision).
    pub fn begin(&self) -> Span {
        let now = self.now_ns();
        self.begin_at(now)
    }

    /// Begin a span whose `accepted` tick was taken earlier (the server
    /// reads the tick at line receipt, then parses, then begins a span
    /// only for inference requests).
    pub fn begin_at(&self, accepted_ns: u64) -> Span {
        self.begun.fetch_add(1, Ordering::Relaxed);
        let mut s = Span::default();
        s.marks[Stage::Accepted as usize] = accepted_ns.max(1);
        if self.sample() {
            s.flags |= flag::SAMPLED;
        }
        s
    }

    fn sample(&self) -> bool {
        match self.period {
            0 => false,
            1 => true,
            p => self.sample_counter.fetch_add(1, Ordering::Relaxed) % p == 0,
        }
    }

    /// Always-capture for a request rejected before completion (shed at
    /// admission, queue-full reject): the span goes to the slow log
    /// with whatever marks it reached.  Caller sets the shed/reject
    /// flag bits first.
    pub fn record_shed(&self, span: &Span) {
        self.anomalies.fetch_add(1, Ordering::Relaxed);
        self.slow.push(span);
        if span.sampled() {
            self.recorded.fetch_add(1, Ordering::Relaxed);
            self.ring_for(span.id as usize).push(span);
        }
    }

    fn ring_for(&self, lane: usize) -> &TraceRing {
        &self.rings[lane % self.rings.len()]
    }

    /// Finish a span at reply-flush time: classify (deadline missed?
    /// slow tail?), retain anomalies in the slow log, record sampled
    /// spans into the `lane`'s trace ring.  Atomics only — this runs on
    /// IO threads and connection threads.
    pub fn complete(&self, span: &mut Span, lane: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let end = span.last_ns();
        let total = end.saturating_sub(span.deadline_basis_ns());
        if span.deadline_ns > 0 && total > span.deadline_ns {
            span.flags |= flag::DEADLINE_MISSED;
        }

        // Reply-flush segment accounting (infer_done → reply_flushed).
        if let (Some(done), Some(flushed)) =
            (span.get(Stage::InferDone), span.get(Stage::ReplyFlushed))
        {
            let d = flushed.saturating_sub(done);
            self.flush_count.fetch_add(1, Ordering::Relaxed);
            self.flush_sum_ns.fetch_add(d, Ordering::Relaxed);
            self.flush_max_ns.fetch_max(d, Ordering::Relaxed);
        }

        // Coarse online p99.9: step toward samples above the estimate,
        // decay slowly below it (≈0.1% of samples above at equilibrium).
        // Lossy under races on purpose — it only gates tail capture.
        let est = self.p999_ns.load(Ordering::Relaxed);
        let warmed = self.completed.load(Ordering::Relaxed) >= SLOW_WARMUP;
        if total > est {
            if warmed && est > 0 {
                span.flags |= flag::SLOW;
            }
            self.p999_ns
                .store(est + (total - est) / 8 + 1, Ordering::Relaxed);
        } else if est > 0 {
            self.p999_ns.store(est - (est / 1024), Ordering::Relaxed);
        }

        let anomaly = span.flags
            & (flag::SHED_PREDICTED
                | flag::SHED_EXPIRED
                | flag::DEADLINE_MISSED
                | flag::SLOW
                | flag::REJECTED)
            != 0;
        if anomaly {
            self.anomalies.fetch_add(1, Ordering::Relaxed);
            self.slow.push(span);
        }
        if span.sampled() {
            self.recorded.fetch_add(1, Ordering::Relaxed);
            self.ring_for(lane).push(span);
        } else if !anomaly {
            // Zero residue: not sampled, not anomalous — nothing retained.
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Last `k` sampled timelines across all rings, newest first.
    pub fn traces(&self, k: usize) -> Vec<Span> {
        let mut all: Vec<Span> = self.rings.iter().flat_map(|r| r.snapshot(k)).collect();
        all.sort_by_key(|s| std::cmp::Reverse(s.last_ns()));
        all.truncate(k);
        all
    }

    /// Last `k` anomaly timelines (always-captured), newest first.
    pub fn slow_log(&self, k: usize) -> Vec<Span> {
        self.slow.snapshot(k)
    }

    pub fn counters(&self) -> ObsCounters {
        let flush_count = self.flush_count.load(Ordering::Relaxed);
        let flush_sum = self.flush_sum_ns.load(Ordering::Relaxed);
        ObsCounters {
            begun: self.begun.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            recorded: self.recorded.load(Ordering::Relaxed),
            sampled_out: self.sampled_out.load(Ordering::Relaxed),
            anomalies: self.anomalies.load(Ordering::Relaxed),
            sample_period: self.period,
            rings: self.rings.len(),
            ring_capacity: self.rings[0].capacity(),
            slow_capacity: self.slow.capacity(),
            p999_est_ms: self.p999_ns.load(Ordering::Relaxed) as f64 / 1e6,
            flush_count,
            flush_mean_ms: if flush_count == 0 {
                0.0
            } else {
                flush_sum as f64 / flush_count as f64 / 1e6
            },
            flush_max_ms: self.flush_max_ns.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_at(id: u64, base_ns: u64) -> Span {
        let mut s = Span {
            id,
            ..Span::default()
        };
        for (i, stage) in [
            Stage::Accepted,
            Stage::Parsed,
            Stage::Admitted,
            Stage::Dequeued,
            Stage::BatchFormed,
            Stage::InferStart,
            Stage::InferDone,
            Stage::ReplyFlushed,
        ]
        .into_iter()
        .enumerate()
        {
            s.set(stage, base_ns + i as u64 * 1_000);
        }
        s
    }

    #[test]
    fn span_marks_are_monotonic_and_summable() {
        let s = span_at(7, 100);
        assert!(s.monotonic());
        assert_eq!(s.first_ns(), 100);
        assert_eq!(s.last_ns(), 100 + 7_000);
        assert!((s.total_ms() - 0.007).abs() < 1e-9);
        assert_eq!(s.get(Stage::InferDone), Some(100 + 6_000));
        let mut bad = s;
        bad.set(Stage::InferDone, 10); // earlier than infer_start
        assert!(!bad.monotonic());
    }

    #[test]
    fn ring_retains_newest_up_to_capacity() {
        let ring = TraceRing::new(4);
        assert!(ring.is_empty());
        for i in 0..10u64 {
            ring.push(&span_at(i, (i + 1) * 1_000_000));
        }
        assert_eq!(ring.len(), 4);
        let got = ring.snapshot(16);
        assert_eq!(got.len(), 4, "never exceeds capacity");
        let ids: Vec<u64> = got.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![9, 8, 7, 6], "newest first");
        assert_eq!(ring.snapshot(2).len(), 2);
    }

    #[test]
    fn sampling_period_tracks_rate() {
        let always = ObsHub::new(1.0, 8, 8, 1);
        let never = ObsHub::new(0.0, 8, 8, 1);
        let tenth = ObsHub::new(0.1, 8, 8, 1);
        assert!(always.begin().sampled());
        assert!(!never.begin().sampled());
        let sampled = (0..1000).filter(|_| tenth.begin().sampled()).count();
        assert_eq!(sampled, 100, "deterministic 1-in-10 head sampling");
        // NaN / negative rates degrade to sampled-out, not panic.
        assert!(!ObsHub::new(f64::NAN, 8, 8, 1).begin().sampled());
        assert!(!ObsHub::new(-0.5, 8, 8, 1).begin().sampled());
    }

    #[test]
    fn sampled_out_leaves_zero_residue() {
        let hub = ObsHub::new(0.0, 64, 64, 2);
        for i in 0..100 {
            let mut s = hub.begin();
            s.id = i;
            s.set(Stage::ReplyFlushed, hub.now_ns());
            hub.complete(&mut s, i as usize);
        }
        assert!(hub.traces(1000).is_empty(), "no ring residue when sampled out");
        assert!(hub.slow_log(1000).is_empty(), "no anomalies, no slow-log residue");
        let c = hub.counters();
        assert_eq!(c.sampled_out, 100);
        assert_eq!(c.recorded, 0);
        assert_eq!(c.anomalies, 0);
    }

    #[test]
    fn deadline_miss_is_always_captured() {
        // Sampling off: capture must come from the anomaly path alone.
        let hub = ObsHub::new(0.0, 8, 8, 1);
        let mut s = hub.begin();
        s.id = 42;
        s.deadline_ns = 1_000_000; // 1ms budget
        let t = s.marks[Stage::Accepted as usize];
        s.set(Stage::Admitted, t + 1);
        s.set(Stage::ReplyFlushed, t + 5_000_000); // 5ms later
        hub.complete(&mut s, 0);
        assert!(s.flags & flag::DEADLINE_MISSED != 0);
        let slow = hub.slow_log(10);
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].id, 42);
        assert_eq!(hub.counters().anomalies, 1);
        assert!(hub.traces(10).is_empty(), "not sampled: ring stays clean");
    }

    #[test]
    fn shed_is_always_captured() {
        let hub = ObsHub::new(0.0, 8, 8, 1);
        let mut s = hub.begin();
        s.id = 9;
        s.flags |= flag::SHED_PREDICTED;
        hub.record_shed(&s);
        let slow = hub.slow_log(10);
        assert_eq!(slow.len(), 1);
        assert!(slow[0].flags & flag::SHED_PREDICTED != 0);
    }

    #[test]
    fn slow_tail_capture_waits_for_warmup() {
        let hub = ObsHub::new(0.0, 8, 1024, 1);
        // Under SLOW_WARMUP completions: a huge outlier is not flagged
        // slow (the estimator has no population yet).
        let mut early = hub.begin();
        early.set(Stage::ReplyFlushed, early.first_ns() + 50_000_000);
        hub.complete(&mut early, 0);
        assert_eq!(early.flags & flag::SLOW, 0);
        // Build a uniform population past warmup, then an outlier must
        // be flagged + retained.
        for _ in 0..(SLOW_WARMUP + 16) {
            let mut s = hub.begin();
            s.set(Stage::ReplyFlushed, s.first_ns() + 1_000_000); // 1ms
            hub.complete(&mut s, 0);
        }
        let mut outlier = hub.begin();
        outlier.id = 777;
        outlier.set(Stage::ReplyFlushed, outlier.first_ns() + 500_000_000);
        hub.complete(&mut outlier, 0);
        assert!(outlier.flags & flag::SLOW != 0, "post-warmup outlier flagged");
        assert!(hub.slow_log(2048).iter().any(|s| s.id == 777));
    }

    #[test]
    fn stage_hist_records_deltas_and_merges() {
        let h = StageHist::new();
        h.record_batch(std::iter::once(span_at(1, 1_000_000)));
        let rows = h.rows();
        // 7 transitions (accepted is a point, not a duration).
        assert_eq!(rows.len(), STAGES - 1);
        assert_eq!(rows[0].stage, "parsed");
        assert_eq!(rows[0].count, 1);
        assert!((rows[0].summary.0 - 0.001).abs() < 1e-9, "1µs delta = 0.001ms");
        // Merge across "models" via Histogram::merge.
        let other = StageHist::new();
        other.record_batch(std::iter::once(span_at(2, 9_000_000)));
        let mut merged = h.histograms();
        for (acc, g) in merged.iter_mut().zip(other.histograms().iter()) {
            acc.merge(g);
        }
        let rows = rows_of(&merged);
        assert_eq!(rows[0].count, 2);
    }

    #[test]
    fn partial_span_skips_unreached_stage_deltas() {
        // A shed span never reaches infer: only the transitions between
        // set marks are recorded, bridging gaps (admitted → flushed).
        let mut s = Span::default();
        s.set(Stage::Accepted, 100);
        s.set(Stage::Parsed, 200);
        s.set(Stage::Admitted, 300);
        s.set(Stage::ReplyFlushed, 500);
        let h = StageHist::new();
        h.record_batch(std::iter::once(s));
        let rows = h.rows();
        let names: Vec<&str> = rows.iter().map(|r| r.stage).collect();
        assert_eq!(names, vec!["parsed", "admitted", "reply_flushed"]);
    }

    #[test]
    fn flag_names_cover_all_bits() {
        assert!(flag_names(0).is_empty());
        let all = flag::SAMPLED
            | flag::SHED_PREDICTED
            | flag::SHED_EXPIRED
            | flag::DEADLINE_MISSED
            | flag::SLOW
            | flag::CACHE_HIT
            | flag::REJECTED;
        assert_eq!(flag_names(all).len(), 7);
        assert_eq!(flag_names(flag::DEADLINE_MISSED), vec!["deadline_missed"]);
    }

    #[test]
    fn counters_report_flush_segment() {
        let hub = ObsHub::new(1.0, 8, 8, 2);
        let mut s = hub.begin();
        let t = s.first_ns();
        s.set(Stage::InferDone, t + 1_000_000);
        s.set(Stage::ReplyFlushed, t + 3_000_000);
        hub.complete(&mut s, 1);
        let c = hub.counters();
        assert_eq!(c.flush_count, 1);
        assert!((c.flush_mean_ms - 2.0).abs() < 1e-6);
        assert!((c.flush_max_ms - 2.0).abs() < 1e-6);
        assert_eq!(c.recorded, 1);
        assert_eq!(hub.traces(10).len(), 1);
    }
}
