//! Deterministic simulation engine — the registry/serving test backend.
//!
//! Every real engine needs compiled HLO artifacts, which means CI (and
//! any box without the Python AOT toolchain) cannot exercise the serving
//! stack end-to-end.  `SimEngine` closes that gap: it is a full
//! [`Engine`] that needs only a manifest (no artifact files, no XLA),
//! runs in microseconds, and produces output that is a pure function of
//! *(model name, input pixels)* — so a test can prove a reply came from
//! the model it addressed, which is exactly the multi-model isolation
//! property the registry must uphold.
//!
//! The output contract (see [`expected_top1`]): the winning class is
//! `(fnv(model) ^ fnv(pixels)) % num_classes`.  Two registry models with
//! different names classify the same frame differently, so any reply
//! crossing — a cache hit leaking across models, a request routed to the
//! wrong pool — shows up as a wrong `top1`, not as a silent pass.
//!
//! A small fixed per-image busy-wait stands in for compute so batching,
//! deadline, and reload-under-load behavior have real time to interleave
//! against (pure zero-cost inference would make "in-flight during
//! reload" an unhittable window).

use anyhow::{bail, Result};
use std::time::{Duration, Instant};

use crate::metrics::ledger::Ledger;
use crate::policy::{bytes_key, image_key};
use crate::runtime::Manifest;
use crate::tensor::{Tensor, TensorView};

/// Simulated per-image execution cost.  Long enough that a burst keeps
/// requests genuinely in flight, short enough that tests stay fast.
pub const SIM_EXEC_PER_IMAGE: Duration = Duration::from_micros(300);

/// Env override for the per-image busy-wait, read at replica build time
/// (`SimEngine::new`), in microseconds.  Tests that need a *slow*
/// engine (e.g. forcing a deadline miss after admission predicted a
/// fast one — see `tests/obs_e2e.rs`) set this after warmup so only
/// replicas built from that point on are inflated.
pub const SIM_EXEC_ENV: &str = "ZULUKO_SIM_EXEC_US";

/// The class the sim engine assigns to `pixels` when served under
/// `model` — the oracle tests compare replies against.
pub fn expected_top1(model: &str, pixels: &[f32], num_classes: usize) -> usize {
    let h = bytes_key(model.as_bytes()) ^ image_key(pixels);
    (h % num_classes.max(1) as u64) as usize
}

pub struct SimEngine {
    model: String,
    num_classes: usize,
    input_hw: usize,
    batch_sizes: Vec<usize>,
    exec_per_image: Duration,
    ledger: Ledger,
}

impl SimEngine {
    pub fn new(manifest: &Manifest) -> Result<SimEngine> {
        let exec_per_image = std::env::var(SIM_EXEC_ENV)
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_micros)
            .unwrap_or(SIM_EXEC_PER_IMAGE);
        Ok(SimEngine {
            model: manifest.model.clone(),
            num_classes: manifest.num_classes.max(1),
            input_hw: manifest.input_hw,
            batch_sizes: if manifest.batch_sizes.is_empty() {
                vec![1]
            } else {
                manifest.batch_sizes.clone()
            },
            exec_per_image,
            ledger: Ledger::new(),
        })
    }

    /// Snapshot fast path: build from the snapshot's embedded manifest —
    /// no manifest.json read.  The busy-wait env override is still read
    /// at build time (same semantics as a cold build), so tests that
    /// inflate later replicas keep working on the snapshot path.
    pub fn from_snapshot(snap: &crate::runtime::ReplicaSnapshot) -> Result<SimEngine> {
        Self::new(&snap.manifest)
    }
}

impl super::Engine for SimEngine {
    fn name(&self) -> &str {
        "sim"
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.infer_view(batch.view())
    }

    fn infer_view(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        let want = [self.input_hw, self.input_hw, 3];
        if batch.shape().len() != 4 || batch.shape()[1..] != want {
            bail!(
                "sim: expected shape [B, {}, {}, 3], got {:?}",
                self.input_hw,
                self.input_hw,
                batch.shape()
            );
        }
        let b = batch.num_rows();
        let mut scores = vec![0.0f32; b * self.num_classes];
        for slot in 0..b {
            let row = batch.row(slot);
            let top1 = expected_top1(&self.model, row.data(), self.num_classes);
            let out = &mut scores[slot * self.num_classes..(slot + 1) * self.num_classes];
            // A deterministic distribution with an unambiguous winner and
            // a stable runner-up, so top-5 extraction is exercised too.
            let floor = 0.05 / self.num_classes as f32;
            out.fill(floor);
            out[top1] = 0.9;
            out[(top1 + 1) % self.num_classes] = 0.04;
            // Busy-wait the simulated compute (sleep granularity on CI
            // runners is too coarse for a 300µs budget).
            let t0 = Instant::now();
            while t0.elapsed() < self.exec_per_image {
                std::hint::spin_loop();
            }
        }
        Tensor::new(&[b, self.num_classes], scores)
    }

    fn warmup(&mut self) -> Result<()> {
        let hw = self.input_hw;
        let x = Tensor::zeros(&[1, hw, hw, 3]);
        self.infer(&x)?;
        self.ledger.clear();
        Ok(())
    }

    fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;

    fn manifest_like(model: &str) -> Manifest {
        // SimEngine only reads these fields; build a Manifest by hand via
        // the testkit synthetic writer to stay honest to the load path.
        let dir = std::env::temp_dir().join(format!(
            "zuluko_sim_unit_{}_{}",
            model,
            std::process::id()
        ));
        crate::testkit::manifest::write_synthetic(&dir, model, 1000, 227, &[1, 2, 4])
            .unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn output_matches_oracle_and_differs_by_model() {
        let ma = manifest_like("alpha");
        let mb = manifest_like("beta");
        let mut a = SimEngine::new(&ma).unwrap();
        let mut b = SimEngine::new(&mb).unwrap();
        let x = Tensor::random(&[2, 227, 227, 3], 9);
        let pa = a.infer(&x).unwrap();
        let pb = b.infer(&x).unwrap();
        assert_eq!(pa.shape(), &[2, 1000]);
        for slot in 0..2 {
            let row = x.view().row(slot);
            let ea = expected_top1("alpha", row.data(), 1000);
            let eb = expected_top1("beta", row.data(), 1000);
            assert_eq!(pa.view().row(slot).argmax(), ea);
            assert_eq!(pb.view().row(slot).argmax(), eb);
        }
    }

    #[test]
    fn rejects_wrong_shape() {
        let m = manifest_like("gamma");
        let mut e = SimEngine::new(&m).unwrap();
        assert!(e.infer(&Tensor::zeros(&[1, 100, 100, 3])).is_err());
        assert!(e.infer(&Tensor::zeros(&[227, 227, 3])).is_err());
    }

    #[test]
    fn deterministic_across_instances() {
        let m = manifest_like("delta");
        let x = Tensor::random(&[1, 227, 227, 3], 4);
        let p1 = SimEngine::new(&m).unwrap().infer(&x).unwrap();
        let p2 = SimEngine::new(&m).unwrap().infer(&x).unwrap();
        assert_eq!(p1.data(), p2.data());
    }
}
