//! The ACL engine — the paper's from-scratch inference engine.
//!
//! What makes it "from scratch" in this reproduction (mirroring the
//! paper's ACL engine structure):
//!
//! * **Fused executables.**  Staged mode runs one executable per network
//!   stage (conv1-block, each fire module with its trailing pool folded
//!   in, the head); fused mode runs the *whole network* as one
//!   executable.  No concatenate op exists anywhere — the fire kernel
//!   writes expand branches into channel slices (L1).
//! * **Weights resident.**  All parameters are XLA literals created once
//!   at load; the request path only builds the input literal.
//! * **Thin dispatch.**  The stage loop is a `for` over a pre-resolved
//!   `Vec<Rc<Executable>>` — no name lookups, no graph walking, no
//!   refcounted registry.  (Contrast with tf.rs, deliberately.)
//!
//! Probe mode is Staged with finer stage boundaries so the ledger can
//! attribute time to the paper's group 1 / group 2 (Fig 3 breakdown).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::metrics::ledger::{Group, Ledger};
use crate::runtime::{
    literal_from_slice, run_timed, tensor_from_literal, Manifest, Runtime,
    StageEntry, WeightStore,
};
use crate::tensor::{Tensor, TensorView};

/// Execution granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// One executable per serving stage (10 stages).
    Staged,
    /// One executable for the whole network.
    Fused,
    /// One executable per probe stage (15; Fig 3 breakdown granularity).
    Probe,
}

/// A stage with its per-batch-size compiled executables and resolved
/// weight literals (resolved once — no lookups on the hot path).
struct CompiledStage {
    name: String,
    group: Group,
    exes: BTreeMap<usize, Rc<xla::PjRtLoadedExecutable>>,
}

pub struct AclEngine {
    mode: Mode,
    name: String,
    stages: Vec<CompiledStage>,
    /// Stage index -> resolved param literal indices into `weights`.
    stage_params: Vec<Vec<String>>,
    weights: WeightStore,
    runtime: Runtime,
    manifest: Manifest,
    ledger: Ledger,
    batch_sizes: Vec<usize>,
}

impl AclEngine {
    pub fn new(manifest: &Manifest, mode: Mode) -> Result<AclEngine> {
        let weights = WeightStore::load(manifest)?;
        Self::with_weights(manifest, mode, weights)
    }

    /// Snapshot fast path: weights come pre-decoded from a validated
    /// [`ReplicaSnapshot`], skipping the weights.bin read + decode.  The
    /// HLO artifacts still compile here — XLA executables are
    /// process-local and cannot be serialized.
    pub fn from_snapshot(
        snap: &crate::runtime::ReplicaSnapshot,
        mode: Mode,
    ) -> Result<AclEngine> {
        let weights =
            WeightStore::from_decoded(&snap.manifest, &snap.f32_bufs, &snap.q8_bufs)?;
        Self::with_weights(&snap.manifest, mode, weights)
    }

    fn with_weights(
        manifest: &Manifest,
        mode: Mode,
        weights: WeightStore,
    ) -> Result<AclEngine> {
        let runtime = Runtime::cpu()?;

        let (entries, batch_sizes): (Vec<StageEntry>, Vec<usize>) = match mode {
            Mode::Staged => (manifest.stages.clone(), manifest.batch_sizes.clone()),
            Mode::Probe => (manifest.probe_stages.clone(), vec![1]),
            Mode::Fused => (Vec::new(), manifest.full.keys().copied().collect()),
        };

        let mut stages = Vec::new();
        let mut stage_params = Vec::new();
        match mode {
            Mode::Fused => {
                let mut exes = BTreeMap::new();
                for (&b, rel) in &manifest.full {
                    exes.insert(b, runtime.load(&manifest.path(rel))?);
                }
                stages.push(CompiledStage {
                    name: "full".into(),
                    group: Group::Other,
                    exes,
                });
                stage_params
                    .push(manifest.params.iter().map(|p| p.name.clone()).collect());
            }
            _ => {
                for st in &entries {
                    let mut exes = BTreeMap::new();
                    for (&b, rel) in &st.artifacts {
                        if batch_sizes.contains(&b) {
                            exes.insert(
                                b,
                                runtime.load(&manifest.path(rel)).with_context(
                                    || format!("stage {} b{}", st.name, b),
                                )?,
                            );
                        }
                    }
                    let group = st
                        .group
                        .as_deref()
                        .map(Group::parse)
                        .unwrap_or(Group::Other);
                    stages.push(CompiledStage {
                        name: st.name.clone(),
                        group,
                        exes,
                    });
                    stage_params.push(st.params.clone());
                }
            }
        }

        let name = match mode {
            Mode::Staged => "acl",
            Mode::Fused => "acl-fused",
            Mode::Probe => "acl-probe",
        };
        Ok(AclEngine {
            mode,
            name: name.to_string(),
            stages,
            stage_params,
            weights,
            runtime,
            manifest: manifest.clone(),
            ledger: Ledger::new(),
            batch_sizes,
        })
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Total time this engine spent in XLA compilation (startup story).
    pub fn compile_time(&self) -> std::time::Duration {
        self.runtime.compile_time()
    }
}

impl super::Engine for AclEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.infer_view(batch.view())
    }

    fn infer_view(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        let b = *batch.shape().first().unwrap_or(&0);
        if !self.batch_sizes.contains(&b) {
            bail!(
                "{}: no artifact for batch {b} (have {:?})",
                self.name,
                self.batch_sizes
            );
        }
        // Input literal straight from the borrowed slice; stages then
        // pass literals hand to hand — no owned Tensor until the final
        // probabilities come back.
        let mut cur = literal_from_slice(batch.shape(), batch.data())?;
        for (stage, params) in self.stages.iter().zip(&self.stage_params) {
            let exe = stage
                .exes
                .get(&b)
                .with_context(|| format!("stage {} missing b{b}", stage.name))?;
            // Pre-resolved literals: params first, activation last (the
            // lowering convention from aot.py).
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 1);
            for p in params {
                args.push(self.weights.literal(p)?);
            }
            args.push(&cur);
            let (out, dt) = run_timed(exe, &args)
                .with_context(|| format!("stage {}", stage.name))?;
            self.ledger.record(&stage.name, stage.group, dt);
            cur = out;
        }
        let probs = tensor_from_literal(&cur)?;
        debug_assert_eq!(probs.shape(), &[b, self.manifest.num_classes]);
        Ok(probs)
    }

    fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }
}
