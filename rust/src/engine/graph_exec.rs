//! Generic op-graph interpreter — the "framework runtime" the paper ported.
//!
//! This is a deliberately faithful miniature of how TensorFlow-style
//! engines execute a model on an embedded device:
//!
//! * a **dynamic tensor registry** keyed by producer name, with
//!   use-counting so intermediates are freed when their last consumer
//!   has run (a framework's memory manager);
//! * **per-op dispatch**: every primitive op — each conv, each ReLU, each
//!   explicit `concat` — crosses the runtime boundary as its own
//!   executable launch;
//! * **full materialization** of every edge (nothing is fused).
//!
//! All per-op wall times land in the ledger under the op's Fig 3 group,
//! which is exactly the instrumentation the paper used for its breakdown.
//! The interpreter is shared by the fp32 baseline (tf.rs) and the
//! quantized baseline (quant.rs).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::metrics::ledger::Ledger;
use crate::model::group_of_kind;
use crate::runtime::{run_timed, Manifest, OpEntry, Runtime, WeightStore};

/// One compiled op with resolved metadata.
pub struct CompiledOp {
    pub entry: OpEntry,
    pub exe: Rc<xla::PjRtLoadedExecutable>,
}

/// Compile every op of a graph (fails fast on any missing artifact).
pub fn compile_graph(
    runtime: &Runtime,
    manifest: &Manifest,
    ops: &[OpEntry],
) -> Result<Vec<CompiledOp>> {
    let mut out = Vec::with_capacity(ops.len());
    for op in ops {
        let exe = runtime
            .load(&manifest.path(&op.artifact))
            .with_context(|| format!("op {} ({})", op.name, op.artifact))?;
        out.push(CompiledOp {
            entry: op.clone(),
            exe,
        });
    }
    Ok(out)
}

/// Peak registry footprint of the last `execute` call, in bytes
/// (framework memory-manager accounting; feeds the Fig 3 memory story).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub peak_registry_bytes: usize,
    pub ops_dispatched: usize,
}

/// Execute the graph on one input literal; returns the final op's output.
///
/// `use_counts` lets the registry free each intermediate after its last
/// consumer, like a framework's ref-counted buffers.
pub fn execute(
    ops: &[CompiledOp],
    weights: &WeightStore,
    input: xla::Literal,
    batch: usize,
    ledger: &mut Ledger,
) -> Result<(xla::Literal, ExecStats)> {
    // Count consumers per producer (computed per call: the registry is
    // dynamic, exactly the overhead a generic runtime pays).
    let mut uses: BTreeMap<&str, usize> = BTreeMap::new();
    for op in ops {
        for i in &op.entry.inputs {
            *uses.entry(i.as_str()).or_insert(0) += 1;
        }
    }

    let mut registry: BTreeMap<&str, (xla::Literal, usize)> = BTreeMap::new();
    let input_uses = *uses.get("input").unwrap_or(&0);
    registry.insert("input", (input, input_uses));

    let mut stats = ExecStats::default();
    let mut last: Option<xla::Literal> = None;

    for op in ops {
        // Gather args: params first, then activations (lowering convention).
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(op.entry.params.len() + op.entry.inputs.len());
        for p in &op.entry.params {
            args.push(weights.literal(p)?);
        }
        for i in &op.entry.inputs {
            let (lit, _) = registry
                .get(i.as_str())
                .with_context(|| format!("op {} input {} not in registry", op.entry.name, i))?;
            args.push(lit);
        }

        let (out, dt) = run_timed(&op.exe, &args)
            .with_context(|| format!("op {}", op.entry.name))?;
        ledger.record(&op.entry.name, group_of_kind(&op.entry.kind), dt);
        stats.ops_dispatched += 1;

        // Release inputs whose last consumer just ran.
        for i in &op.entry.inputs {
            let remove = {
                let (_, cnt) = registry.get_mut(i.as_str()).unwrap();
                *cnt -= 1;
                *cnt == 0
            };
            if remove {
                registry.remove(i.as_str());
            }
        }

        let op_uses = *uses.get(op.entry.name.as_str()).unwrap_or(&0);
        if op_uses == 0 {
            // Terminal op (or dead code): keep as candidate output.
            last = Some(out);
        } else {
            registry.insert(op.entry.name.as_str(), (out, op_uses));
        }

        // Footprint = sum of live edges (manifest shapes are exact).
        let live: usize = registry
            .iter()
            .map(|(name, _)| {
                if *name == "input" {
                    batch * 227 * 227 * 3 * 4
                } else {
                    ops.iter()
                        .find(|o| o.entry.name == *name)
                        .map(|o| {
                            crate::model::edge_bytes(
                                &o.entry.out_shape,
                                &o.entry.out_dtype,
                                batch,
                            )
                        })
                        .unwrap_or(0)
                }
            })
            .sum();
        stats.peak_registry_bytes = stats.peak_registry_bytes.max(live);
    }

    match last {
        Some(l) => Ok((l, stats)),
        None => bail!("graph has no terminal op"),
    }
}
