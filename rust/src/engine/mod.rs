//! Execution engines — the paper's two protagonists plus the Fig 4 variant.
//!
//! * [`acl::AclEngine`] — the **from-scratch** engine: fused per-stage (or
//!   single fully-fused) executables, weights resident, no concat ops, no
//!   graph interpretation.  The paper's contribution.
//! * [`tf::TfBaselineEngine`] — the **ported-framework** baseline: a
//!   generic graph interpreter dispatching one executable per primitive
//!   op through a dynamic tensor registry, materializing every
//!   intermediate (including the fire-module concats).
//! * [`quant::QuantEngine`] — the baseline with Fig 4's int8 graph surgery
//!   (quantize / conv_q8 / dequantize+bias per conv).
//!
//! Both baselines run the *same* L1 Pallas kernels as the ACL engine, so
//! measured deltas are pure engine structure (DESIGN.md §Substitutions).

pub mod acl;
pub mod graph_exec;
pub mod quant;
pub mod sim;
pub mod tf;

use anyhow::Result;

use crate::metrics::ledger::Ledger;
use crate::runtime::Manifest;
use crate::tensor::{Tensor, TensorView};

/// A batch-in, probabilities-out inference engine.
///
/// `infer` takes `(B, 227, 227, 3)` and returns `(B, 1000)` softmax
/// probabilities.  Engines are single-threaded by design (XLA handles are
/// not Send); the coordinator gives each worker thread its own instance.
pub trait Engine {
    /// Short id: "acl", "acl-fused", "acl-probe", "tf", "quant".
    fn name(&self) -> &str;

    /// Batch sizes with compiled artifacts (1 always included).
    fn batch_sizes(&self) -> Vec<usize>;

    /// Run one batch.
    fn infer(&mut self, batch: &Tensor) -> Result<Tensor>;

    /// Run one batch from a borrowed view — the zero-copy serving entry
    /// point (the worker's batch lives in a pooled buffer it owns).
    /// The default copies into an owned tensor; the in-tree engines
    /// override it to build their input literals straight from the
    /// borrowed slice.
    fn infer_view(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        let owned = batch.to_tensor();
        self.infer(&owned)
    }

    /// Cumulative per-op/per-stage timing ledger (cleared by callers
    /// between measurement windows).
    fn ledger(&self) -> &Ledger;
    fn ledger_mut(&mut self) -> &mut Ledger;

    /// Compile + run everything once so later timings exclude compilation.
    fn warmup(&mut self) -> Result<()> {
        let hw = 227;
        let x = Tensor::zeros(&[1, hw, hw, 3]);
        self.infer(&x)?;
        self.ledger_mut().clear();
        Ok(())
    }
}

/// Engine selector used by the CLI / config / benches.  `Ord`/`Hash`
/// let the policy layer key predictor tables by engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EngineKind {
    /// ACL, per-stage fused executables (default serving mode).
    AclStaged,
    /// ACL, one fully-fused executable per batch size.
    AclFused,
    /// ACL at probe granularity (Fig 3 group breakdown).
    AclProbe,
    /// TF-baseline op-by-op graph interpreter.
    TfBaseline,
    /// Quantized baseline (Fig 4).
    Quant,
    /// Deterministic simulation engine: no artifacts, output is a pure
    /// function of (model name, pixels).  The registry / serving test
    /// backend — see engine::sim.
    Sim,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        Ok(match s {
            "acl" | "acl-staged" => EngineKind::AclStaged,
            "acl-fused" => EngineKind::AclFused,
            "acl-probe" => EngineKind::AclProbe,
            "tf" | "tf-baseline" => EngineKind::TfBaseline,
            "quant" | "tf-quant" => EngineKind::Quant,
            "sim" => EngineKind::Sim,
            _ => anyhow::bail!(
                "unknown engine '{s}' (acl|acl-fused|acl-probe|tf|quant|sim)"
            ),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::AclStaged => "acl",
            EngineKind::AclFused => "acl-fused",
            EngineKind::AclProbe => "acl-probe",
            EngineKind::TfBaseline => "tf",
            EngineKind::Quant => "quant",
            EngineKind::Sim => "sim",
        }
    }
}

/// Build an engine (fresh Runtime + WeightStore per instance; see trait
/// docs for the threading rationale).
pub fn build(kind: EngineKind, manifest: &Manifest) -> Result<Box<dyn Engine>> {
    Ok(match kind {
        EngineKind::AclStaged => {
            Box::new(acl::AclEngine::new(manifest, acl::Mode::Staged)?)
        }
        EngineKind::AclFused => {
            Box::new(acl::AclEngine::new(manifest, acl::Mode::Fused)?)
        }
        EngineKind::AclProbe => {
            Box::new(acl::AclEngine::new(manifest, acl::Mode::Probe)?)
        }
        EngineKind::TfBaseline => Box::new(tf::TfBaselineEngine::new(manifest)?),
        EngineKind::Quant => Box::new(quant::QuantEngine::new(manifest)?),
        EngineKind::Sim => Box::new(sim::SimEngine::new(manifest)?),
    })
}

/// Build an engine from a validated [`ReplicaSnapshot`] — the AOT
/// fast path.  Weights arrive pre-decoded in engine-ready layout and the
/// manifest comes from the snapshot's embedded text, so construction
/// skips every artifact-directory read except HLO compilation (XLA
/// executables are process-local and cannot be serialized).
///
/// Callers decide warm-up: if `snap.warm_covers(kind)` the probe warm-up
/// that ran at capture time stands in for a fresh one.  Any `Err` here
/// means "fall back to [`build`]" — a snapshot is never load-bearing.
pub fn build_from_snapshot(
    kind: EngineKind,
    snap: &crate::runtime::ReplicaSnapshot,
) -> Result<Box<dyn Engine>> {
    Ok(match kind {
        EngineKind::AclStaged => {
            Box::new(acl::AclEngine::from_snapshot(snap, acl::Mode::Staged)?)
        }
        EngineKind::AclFused => {
            Box::new(acl::AclEngine::from_snapshot(snap, acl::Mode::Fused)?)
        }
        EngineKind::AclProbe => {
            Box::new(acl::AclEngine::from_snapshot(snap, acl::Mode::Probe)?)
        }
        EngineKind::TfBaseline => Box::new(tf::TfBaselineEngine::from_snapshot(snap)?),
        EngineKind::Quant => Box::new(quant::QuantEngine::from_snapshot(snap)?),
        EngineKind::Sim => Box::new(sim::SimEngine::from_snapshot(snap)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            EngineKind::AclStaged,
            EngineKind::AclFused,
            EngineKind::AclProbe,
            EngineKind::TfBaseline,
            EngineKind::Quant,
            EngineKind::Sim,
        ] {
            assert_eq!(EngineKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(EngineKind::parse("pytorch").is_err());
    }
}
