//! The quantized baseline engine — Fig 4's experiment.
//!
//! Same generic graph interpreter as tf.rs, but over the quantized graph:
//! every conv becomes `quantize -> conv_q8 -> dequantize+bias` (118 ops
//! total vs the fp32 baseline's 66).  The ledger's `Quant` group collects
//! exactly the re-quantize / de-quantize overhead the paper blames for the
//! end-to-end slowdown; `Group1` collects the (cheaper) int8 convs.

use anyhow::Result;

use crate::metrics::ledger::Ledger;
use crate::runtime::{
    literal_from_tensor, tensor_from_literal, Manifest, Runtime, WeightStore,
};
use crate::tensor::Tensor;

use super::graph_exec::{self, CompiledOp, ExecStats};

pub struct QuantEngine {
    ops: Vec<CompiledOp>,
    weights: WeightStore,
    #[allow(dead_code)] // owns the executables' client
    runtime: Runtime,
    ledger: Ledger,
    num_classes: usize,
    pub last_stats: ExecStats,
}

impl QuantEngine {
    pub fn new(manifest: &Manifest) -> Result<QuantEngine> {
        let runtime = Runtime::cpu()?;
        let weights = WeightStore::load(manifest)?;
        let ops = graph_exec::compile_graph(&runtime, manifest, &manifest.quant_ops)?;
        Ok(QuantEngine {
            ops,
            weights,
            runtime,
            ledger: Ledger::new(),
            num_classes: manifest.num_classes,
            last_stats: ExecStats::default(),
        })
    }

    pub fn ops_per_image(&self) -> usize {
        self.ops.len()
    }
}

impl super::Engine for QuantEngine {
    fn name(&self) -> &str {
        "quant"
    }

    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        let images = if batch.shape().first() == Some(&1) {
            vec![batch.clone()]
        } else {
            batch
                .unstack()?
                .into_iter()
                .map(|t| {
                    let mut shape = vec![1];
                    shape.extend(t.shape());
                    t.reshape(&shape.clone()).unwrap()
                })
                .collect()
        };

        let mut rows = Vec::with_capacity(images.len());
        for img in &images {
            let input = literal_from_tensor(img)?;
            let (out, stats) = graph_exec::execute(
                &self.ops,
                &self.weights,
                input,
                1,
                &mut self.ledger,
            )?;
            self.last_stats = stats;
            rows.push(tensor_from_literal(&out)?);
        }
        let refs: Vec<&Tensor> = rows.iter().collect();
        Tensor::stack(&refs)?.reshape(&[images.len(), self.num_classes])
    }

    fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }
}
