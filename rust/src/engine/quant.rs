//! The quantized baseline engine — Fig 4's experiment.
//!
//! Same generic graph interpreter as tf.rs, but over the quantized graph:
//! every conv becomes `quantize -> conv_q8 -> dequantize+bias` (118 ops
//! total vs the fp32 baseline's 66).  The ledger's `Quant` group collects
//! exactly the re-quantize / de-quantize overhead the paper blames for the
//! end-to-end slowdown; `Group1` collects the (cheaper) int8 convs.

use anyhow::Result;

use crate::metrics::ledger::Ledger;
use crate::runtime::{
    literal_from_slice, tensor_from_literal, Manifest, Runtime, WeightStore,
};
use crate::tensor::{Tensor, TensorView};

use super::graph_exec::{self, CompiledOp, ExecStats};

pub struct QuantEngine {
    ops: Vec<CompiledOp>,
    weights: WeightStore,
    #[allow(dead_code)] // owns the executables' client
    runtime: Runtime,
    ledger: Ledger,
    num_classes: usize,
    pub last_stats: ExecStats,
}

impl QuantEngine {
    pub fn new(manifest: &Manifest) -> Result<QuantEngine> {
        let weights = WeightStore::load(manifest)?;
        Self::with_weights(manifest, weights)
    }

    /// Snapshot fast path: pre-decoded weights from a validated
    /// [`crate::runtime::ReplicaSnapshot`]; the quantized op graph still
    /// compiles here (XLA handles are process-local).
    pub fn from_snapshot(snap: &crate::runtime::ReplicaSnapshot) -> Result<QuantEngine> {
        let weights =
            WeightStore::from_decoded(&snap.manifest, &snap.f32_bufs, &snap.q8_bufs)?;
        Self::with_weights(&snap.manifest, weights)
    }

    fn with_weights(manifest: &Manifest, weights: WeightStore) -> Result<QuantEngine> {
        let runtime = Runtime::cpu()?;
        let ops = graph_exec::compile_graph(&runtime, manifest, &manifest.quant_ops)?;
        Ok(QuantEngine {
            ops,
            weights,
            runtime,
            ledger: Ledger::new(),
            num_classes: manifest.num_classes,
            last_stats: ExecStats::default(),
        })
    }

    pub fn ops_per_image(&self) -> usize {
        self.ops.len()
    }
}

impl super::Engine for QuantEngine {
    fn name(&self) -> &str {
        "quant"
    }

    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.infer_view(batch.view())
    }

    fn infer_view(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        if batch.shape().is_empty() {
            anyhow::bail!("quant: scalar batch");
        }
        // Same borrowed-row iteration as the fp32 baseline (tf.rs): one
        // literal per image built straight from the batch buffer slice.
        let n = batch.num_rows();
        let mut rshape = Vec::with_capacity(batch.shape().len());
        rshape.push(1);
        rshape.extend_from_slice(&batch.shape()[1..]);
        let mut data = Vec::with_capacity(n * self.num_classes);
        for i in 0..n {
            let row = batch.row(i);
            let input = literal_from_slice(&rshape, row.data())?;
            let (out, stats) = graph_exec::execute(
                &self.ops,
                &self.weights,
                input,
                1,
                &mut self.ledger,
            )?;
            self.last_stats = stats;
            data.extend_from_slice(tensor_from_literal(&out)?.data());
        }
        Tensor::new(&[n, self.num_classes], data)
    }

    fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }
}
