//! The TF-baseline engine — a miniature of the ported framework the paper
//! measured against.
//!
//! Executes the SqueezeNet op graph (66 primitive ops, including the 8
//! explicit fire-module concats) through the generic interpreter in
//! graph_exec.rs.  Every op is its own executable dispatch; every edge is
//! materialized; concat is a real copy.  The compute inside each op comes
//! from the *same* Pallas kernels the ACL engine uses — measured deltas
//! are engine structure only (the paper's "both use NEON" control).
//!
//! Batch handling: like a framework with a fixed batch-1 graph, batches
//! are processed image-by-image (the paper also reports per-image
//! latency).

use anyhow::Result;

use crate::metrics::ledger::Ledger;
use crate::runtime::{
    literal_from_slice, tensor_from_literal, Manifest, Runtime, WeightStore,
};
use crate::tensor::{Tensor, TensorView};

use super::graph_exec::{self, CompiledOp, ExecStats};

pub struct TfBaselineEngine {
    ops: Vec<CompiledOp>,
    weights: WeightStore,
    #[allow(dead_code)] // owns the executables' client
    runtime: Runtime,
    ledger: Ledger,
    num_classes: usize,
    pub last_stats: ExecStats,
}

impl TfBaselineEngine {
    pub fn new(manifest: &Manifest) -> Result<TfBaselineEngine> {
        let weights = WeightStore::load(manifest)?;
        Self::with_weights(manifest, weights)
    }

    /// Snapshot fast path: pre-decoded weights from a validated
    /// [`crate::runtime::ReplicaSnapshot`]; op executables still compile
    /// (XLA handles are process-local).
    pub fn from_snapshot(
        snap: &crate::runtime::ReplicaSnapshot,
    ) -> Result<TfBaselineEngine> {
        let weights =
            WeightStore::from_decoded(&snap.manifest, &snap.f32_bufs, &snap.q8_bufs)?;
        Self::with_weights(&snap.manifest, weights)
    }

    fn with_weights(manifest: &Manifest, weights: WeightStore) -> Result<TfBaselineEngine> {
        let runtime = Runtime::cpu()?;
        let ops = graph_exec::compile_graph(&runtime, manifest, &manifest.ops)?;
        Ok(TfBaselineEngine {
            ops,
            weights,
            runtime,
            ledger: Ledger::new(),
            num_classes: manifest.num_classes,
            last_stats: ExecStats::default(),
        })
    }

    pub fn ops_per_image(&self) -> usize {
        self.ops.len()
    }
}

impl super::Engine for TfBaselineEngine {
    fn name(&self) -> &str {
        "tf"
    }

    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        self.infer_view(batch.view())
    }

    fn infer_view(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        if batch.shape().is_empty() {
            anyhow::bail!("tf: scalar batch");
        }
        // Image-by-image like a fixed batch-1 framework graph, but each
        // per-image literal is built from a borrowed row view — no
        // clone, no unstack copies.
        let n = batch.num_rows();
        let mut rshape = Vec::with_capacity(batch.shape().len());
        rshape.push(1);
        rshape.extend_from_slice(&batch.shape()[1..]);
        let mut data = Vec::with_capacity(n * self.num_classes);
        for i in 0..n {
            let row = batch.row(i);
            let input = literal_from_slice(&rshape, row.data())?;
            let (out, stats) = graph_exec::execute(
                &self.ops,
                &self.weights,
                input,
                1,
                &mut self.ledger,
            )?;
            self.last_stats = stats;
            // Each output is (1, C); append its row into the (B, C) pack.
            data.extend_from_slice(tensor_from_literal(&out)?.data());
        }
        Tensor::new(&[n, self.num_classes], data)
    }

    fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }
}
