//! One immutable serving generation of one model: scheduled queues, a
//! tensor arena, and per-generation policy state.  **No threads** — the
//! shared worker runtime (coordinator::scheduler) executes every
//! generation's work on a fixed process-wide fleet.
//!
//! A generation is built *cold* (load manifest → probe-build + warm one
//! replica per engine kind, failing fast on any build error → register
//! its queues with the scheduler) and only then published by the
//! registry, so requests never observe an unbuildable model.  After a
//! hot reload retires it, the generation drains gracefully:
//!
//! * its queues close (graceful: residual items still pop), so every
//!   request already admitted is served by the *old* weights — runtime
//!   workers keep serving closed non-empty queues;
//! * [`Generation::retire`] waits on the scheduler's drain condition
//!   (queue closed + empty + zero in-flight batches) and then
//!   deregisters the queues — no thread joins anywhere;
//! * the `Generation` itself (arena handle, policy ctx, manifest) is
//!   kept alive by `Arc` until the last [`super::GenerationLease`]
//!   drops, and `Drop` re-runs `retire` as an idempotent backstop;
//! * worker-side engine replicas of a retired generation are evicted
//!   from the per-worker replica caches once its queues leave the
//!   scheduler table.
//!
//! Policy state is **per generation** on purpose: a reload means new
//! weights, and a response cache or latency EWMA carried across weights
//! would serve stale classifications / stale predictions.  Cache keys
//! therefore can never cross models *or* generations.

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::Config;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::router::{EnginePort, RouteError};
use crate::coordinator::scheduler::{ExecCtx, QueueKey, RuntimeHandle, WorkSource};
use crate::coordinator::worker::SharedStats;
use crate::coordinator::{ReplySink, Request, Response, SubmitError};
use crate::engine::{self, EngineKind};
use crate::metrics::Histogram;
use crate::obs::{flag, Span, Stage, StageHist};
use crate::policy::{
    self, image_key, Decision, PolicyCtx, PoolSnapshot, PoolView, Selector, Slo,
};
use crate::runtime::Manifest;
use crate::tensor::{PooledTensor, TensorPool};
use crate::util::log::{suppressed_note, SHED_LOG};

use super::ModelCounters;

/// Batch sizes a given engine kind has compiled artifacts for.
fn supported_sizes(kind: EngineKind, manifest: &Manifest) -> Vec<usize> {
    match kind {
        EngineKind::AclStaged | EngineKind::Sim => manifest.batch_sizes.clone(),
        EngineKind::AclFused => manifest.full.keys().copied().collect(),
        _ => vec![1],
    }
}

/// One warmed serving generation of one model (see module docs).
pub struct Generation {
    model: Arc<str>,
    generation: u64,
    input_hw: usize,
    /// Admission ports, in quality order (quality engine first).
    ports: Vec<EnginePort>,
    runtime: RuntimeHandle,
    selector: Selector,
    ctx: Arc<PolicyCtx>,
    arena: TensorPool,
    /// Process-wide aggregates (survive reloads; shared across models).
    stats: Arc<SharedStats>,
    /// Per-model counters (survive reloads; shared across generations).
    counters: Arc<ModelCounters>,
    /// Per-generation stage-latency histograms (DESIGN.md §10): the
    /// runtime workers record served batches' span deltas here;
    /// `{"cmd":"metrics"}` merges them across models.
    stage_hist: Arc<StageHist>,
    /// Wall time spent probe-building + warming one replica per engine
    /// kind (artifact validation; see `start`).
    warm_ms: f64,
    /// Content hash of the artifacts this generation was built from
    /// (manifest + weight files; see
    /// [`crate::runtime::artifact_content_hash`]).  The registry's
    /// no-op reload short-circuit compares against it.
    content_hash: u64,
    retired: AtomicBool,
}

impl Generation {
    /// Load the manifest at `artifacts`, validate it by building and
    /// warming one engine replica per configured kind on this thread
    /// (then dropping it — replicas are rebuilt inside runtime workers,
    /// where they can live, because XLA handles are not `Send`), and
    /// register the generation's queues with the shared scheduler.
    /// Returns only when the model is proven servable — or fails fast —
    /// which is what keeps reloads atomic: nothing is published before
    /// this returns.
    ///
    /// Tradeoff vs. the per-generation-workers era: the probe proves
    /// buildability but each runtime worker still pays one inline
    /// replica build on its first batch for this generation (DESIGN.md
    /// §4 "Known tradeoff") — deadline shedding stays structured
    /// throughout, and `warm_ms` measures the probe, not per-worker
    /// readiness.
    pub(super) fn start(
        model: Arc<str>,
        generation: u64,
        artifacts: &std::path::Path,
        cfg: &Config,
        runtime: RuntimeHandle,
        stats: Arc<SharedStats>,
        counters: Arc<ModelCounters>,
    ) -> Result<Generation> {
        let t0 = Instant::now();
        let content_hash = crate::runtime::artifact_content_hash(artifacts)
            .with_context(|| format!("hashing artifacts for model '{model}'"))?;

        // AOT snapshot fast path (DESIGN.md §11): a valid `.zsnap` next
        // to the manifest carries the parsed manifest and pre-decoded
        // weight buffers from a previous build of these exact artifacts
        // (content-addressed — `load` rejects anything stale, corrupt,
        // or version-skewed).  Any load failure is a cold build, never
        // an error.
        let mut snap: Option<Arc<crate::runtime::ReplicaSnapshot>> = None;
        if cfg.snapshots.reads() {
            match crate::runtime::ReplicaSnapshot::load(artifacts) {
                Ok(s) => {
                    counters.snapshot_hits.fetch_add(1, Ordering::Relaxed);
                    snap = Some(Arc::new(s));
                }
                Err(e) => {
                    counters.snapshot_misses.fetch_add(1, Ordering::Relaxed);
                    crate::info!(
                        "registry",
                        "model '{model}': no usable snapshot ({e:#}); cold build"
                    );
                }
            }
        }
        let manifest = match &snap {
            Some(s) => s.manifest.clone(),
            None => Manifest::load(artifacts)
                .with_context(|| format!("loading manifest for model '{model}'"))?,
        };

        // With `cfg.policy.adaptive`, two queues come up — the
        // configured engine (quality path) plus the int8 quant path —
        // and the SLO selector routes between them per request.
        let kinds: Vec<EngineKind> = if cfg.policy.adaptive {
            vec![cfg.engine, EngineKind::Quant]
        } else {
            vec![cfg.engine]
        };

        // Probe-build: prove every engine kind builds + warms before
        // anything is published.  The probe replica is dropped — it
        // validated the artifacts; serving replicas are built inside
        // the runtime workers' threads on first batch.  With a snapshot
        // in hand the probe builds from pre-decoded buffers and skips
        // the warm-up for kinds the snapshot's warm-plan covers (the
        // capture-time warm-up stands in); a snapshot-path failure
        // falls back to the cold build for that kind.
        for &kind in &kinds {
            let (mut probe, prewarmed) = match &snap {
                Some(s) => match engine::build_from_snapshot(kind, s) {
                    Ok(p) => (p, s.warm_covers(kind)),
                    Err(e) => {
                        counters.snapshot_fallbacks.fetch_add(1, Ordering::Relaxed);
                        crate::warn!(
                            "registry",
                            "model '{model}': snapshot probe for {} failed \
                             ({e:#}); cold-building",
                            kind.as_str()
                        );
                        let p = engine::build(kind, &manifest).with_context(|| {
                            format!("model '{model}': building {} probe", kind.as_str())
                        })?;
                        (p, false)
                    }
                },
                None => {
                    let p = engine::build(kind, &manifest).with_context(|| {
                        format!("model '{model}': building {} probe", kind.as_str())
                    })?;
                    (p, false)
                }
            };
            if !prewarmed {
                probe.warmup().with_context(|| {
                    format!("model '{model}': warming {} probe", kind.as_str())
                })?;
            }
        }

        // Capture the snapshot after a successful cold probe (On mode
        // with no valid snapshot on disk, or Refresh mode, which always
        // rebuilds and rewrites).  The captured snapshot also rides
        // along in memory (ExecCtx below) so this generation's worker
        // replicas build snapshot-fast even on the very first cold
        // start.  Write failures are logged, never fatal — the build
        // already proved itself.
        if cfg.snapshots.writes() && snap.is_none() {
            match crate::runtime::ReplicaSnapshot::capture(&manifest, &kinds) {
                Ok(s) => match s.write(artifacts) {
                    Ok(()) => {
                        crate::info!(
                            "registry",
                            "model '{model}': wrote replica snapshot (hash {:016x})",
                            content_hash
                        );
                        snap = Some(Arc::new(s));
                    }
                    Err(e) => crate::warn!(
                        "registry",
                        "model '{model}': snapshot write failed: {e:#}"
                    ),
                },
                Err(e) => crate::warn!(
                    "registry",
                    "model '{model}': snapshot capture failed: {e:#}"
                ),
            }
        }

        let ctx = Arc::new(PolicyCtx::new(
            cfg.policy.ewma_alpha,
            cfg.policy.cache_capacity,
        ));
        for &kind in &kinds {
            ctx.predictor.seed(kind, 1, policy::default_prior_ms(kind));
        }

        // Tensor arena for this model's request path: decode buffers
        // plus batch buffers per compiled batch size, shelved at startup
        // so the steady state never allocates pixels.  Batch classes are
        // reserved at the runtime fleet size — at most that many batches
        // can be in flight at once.
        let input_len = manifest.input_hw * manifest.input_hw * 3;
        let arena = TensorPool::with_mode(cfg.pool.enabled, cfg.pool.per_class_cap);
        arena.prealloc(input_len, cfg.queue_capacity);

        let weight = cfg.registry.weight_for(&model);
        let stage_hist = Arc::new(StageHist::new());
        let exec = Arc::new(ExecCtx {
            model: model.clone(),
            generation,
            manifest: manifest.clone(),
            arena: arena.clone(),
            ctx: ctx.clone(),
            counters: counters.clone(),
            stage_hist: stage_hist.clone(),
            snapshot: snap.clone(),
            snapshots_on: cfg.snapshots.reads() || cfg.snapshots.writes(),
        });

        let mut ports = Vec::with_capacity(kinds.len());
        for (i, &kind) in kinds.iter().enumerate() {
            let supported = supported_sizes(kind, &manifest);
            for &b in supported.iter().filter(|&&b| b <= cfg.max_batch) {
                // Warm a couple of batch buffers per class — NOT one
                // per runtime worker: at most `workers` batch leases
                // exist process-wide across ALL models, so an eager
                // fleet-sized reservation per (model, kind, class)
                // would multiply resident memory N-models-fold.  Rare
                // bursts beyond the warm count allocate once and then
                // shelve under the pool's per-class retention cap.
                arena.prealloc(b * input_len, runtime.workers.min(2));
            }
            let policy = BatchPolicy::new(cfg.max_batch, cfg.batch_timeout, &supported);
            let source = Arc::new(WorkSource::new(
                QueueKey {
                    model: model.clone(),
                    generation,
                    engine: kind,
                },
                Arc::new(BoundedQueue::new(cfg.queue_capacity)),
                policy,
                weight,
                // Only the quality queue (kinds[0]) fills the response
                // cache so hits never downgrade accuracy to the int8
                // path.
                i == 0,
                exec.clone(),
            ));
            runtime.scheduler.register(source.clone());
            ports.push(EnginePort::new(source, runtime.scheduler.clone()));
        }

        let warm_ms = crate::util::ms(t0.elapsed());
        crate::info!(
            "registry",
            "model '{}' gen {} ready in {:.0}ms: queues={:?} weight={} max_batch={}",
            model,
            generation,
            warm_ms,
            kinds.iter().map(|k| k.as_str()).collect::<Vec<_>>(),
            weight,
            cfg.max_batch,
        );

        Ok(Generation {
            model,
            generation,
            input_hw: manifest.input_hw,
            ports,
            runtime,
            selector: Selector::new(cfg.policy.margin, 1),
            ctx,
            arena,
            stats,
            counters,
            stage_hist,
            warm_ms,
            content_hash,
            retired: AtomicBool::new(false),
        })
    }

    /// Content hash of the artifacts this generation was built from
    /// (the registry's no-op reload detector).
    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Monotonic per-model generation number (1 = first load).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn input_hw(&self) -> usize {
        self.input_hw
    }

    /// Wall time spent validating (probe-building + warming) this
    /// generation's engines.
    pub fn warm_ms(&self) -> f64 {
        self.warm_ms
    }

    /// This model's tensor arena (decode buffers lease from here).
    pub fn arena(&self) -> TensorPool {
        self.arena.clone()
    }

    /// This generation's policy state (per-model predictor + cache).
    pub fn ctx(&self) -> &Arc<PolicyCtx> {
        &self.ctx
    }

    /// Clones of this generation's per-stage latency histograms, index
    /// = [`Stage`] (merged across models by
    /// [`crate::coordinator::Coordinator::metrics`]).
    pub fn stage_histograms(&self) -> Vec<Histogram> {
        self.stage_hist.histograms()
    }

    /// Requests queued across this generation's queues.
    pub fn queued(&self) -> usize {
        self.ports.iter().map(EnginePort::queued).sum()
    }

    /// Reject wrong-shaped inputs before they touch queues or the arena.
    fn check_shape(&self, shape: &[usize]) -> Result<(), SubmitError> {
        let want = [self.input_hw, self.input_hw, 3];
        if shape != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {want:?}, got {shape:?}"
            )));
        }
        Ok(())
    }

    fn count_rejected(&self) {
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        self.counters.rejected.fetch_add(1, Ordering::Relaxed);
    }

    fn cache_hit_response(&self, id: u64, hit: &policy::CachedResult, total_ms: f64) -> Response {
        let mut r = Response::cache_hit(id, hit, total_ms);
        r.model = self.model.clone();
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.counters.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.latency.lock().unwrap().record_ms(total_ms);
        r
    }

    /// Response-cache lookup by an externally computed key — the
    /// server's wire-key fast path.  A hit means the caller can skip
    /// image decode entirely; a miss is not counted against the cache
    /// (the post-decode content-key lookup counts once per request).
    /// Keys live in this generation's cache only, so a hit can never
    /// cross models or weight generations.
    pub fn cached_response(&self, key: u64) -> Option<Response> {
        if !self.ctx.cache.enabled() {
            return None;
        }
        let t0 = Instant::now();
        let hit = self.ctx.cache.peek(key)?;
        // Measured, like the content-key hit path — cache hits are real
        // requests with (near-zero) real latency.
        let total_ms = crate::util::ms(t0.elapsed());
        Some(self.cache_hit_response(0, &hit, total_ms))
    }

    /// Zero-copy submission onto this generation — see
    /// [`Generation::submit_pooled_reclaim`]; this wrapper discards the
    /// reclaimed image for callers that don't retry.
    pub fn submit_pooled(
        &self,
        id: u64,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_pooled_reclaim(id, image, slo, wire_key).map_err(|(e, _img)| e)
    }

    /// Channel-flavored wrapper over [`Generation::submit_sink_reclaim`]
    /// for synchronous callers: the reply arrives on the returned
    /// receiver (a cache hit is already in it by the time this returns).
    pub fn submit_pooled_reclaim(
        &self,
        id: u64,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, (SubmitError, Option<PooledTensor>)> {
        let (tx, rx) = mpsc::channel();
        self.submit_sink_reclaim(id, image, slo, wire_key, ReplySink::channel(tx))
            .map(|_| rx)
    }

    /// Zero-copy submission onto this generation: the image already
    /// lives in a pooled lease (ideally from [`Generation::arena`]).
    /// The cache is consulted first (a hit replies immediately without
    /// touching an engine); otherwise the selector routes to the best
    /// engine queue predicted to meet the deadline, or sheds.
    /// `wire_key` optionally keys the response cache on the raw request
    /// bytes so a repeat of the same wire spec skips decode next time.
    ///
    /// `Ok(())` means exactly one [`Response`] reaches `reply` —
    /// immediately on a cache hit, from a runtime worker otherwise, or
    /// from the sink's drop backstop if the queue is torn down with the
    /// request inside.  `Err` means nothing was delivered (the sink is
    /// disarmed): the caller owns the structured error.
    ///
    /// On `Closed` (this generation retired mid-swap) the decoded image
    /// is handed back alongside the error so the caller can re-resolve
    /// and resubmit the *same pixels* to the fresh generation without
    /// re-decoding.
    pub fn submit_sink_reclaim(
        &self,
        id: u64,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
        reply: ReplySink,
    ) -> Result<(), (SubmitError, Option<PooledTensor>)> {
        let span = self.stats.obs.begin();
        self.submit_sink_traced(id, image, slo, wire_key, reply, span)
    }

    /// [`Generation::submit_sink_reclaim`] with a caller-provided trace
    /// span (DESIGN.md §10): the connection plane begins the span at
    /// accept time so the timeline covers parse + admission, not just
    /// queue + inference.  Stamps `admitted` here; shed/reject paths
    /// record the span into the hub's anomaly log before returning.
    pub fn submit_sink_traced(
        &self,
        id: u64,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
        reply: ReplySink,
        mut span: Span,
    ) -> Result<(), (SubmitError, Option<PooledTensor>)> {
        span.id = id;
        if let Some(ms) = slo.deadline_ms() {
            span.deadline_ns = (ms * 1e6) as u64;
        }
        if let Err(e) = self.check_shape(image.shape()) {
            reply.disarm();
            return Err((e, Some(image)));
        }
        let submitted = Instant::now();

        // Response cache: repeated frames skip inference entirely.
        let cache_key = if self.ctx.cache.enabled() {
            let key = image_key(image.data());
            if let Some(hit) = self.ctx.cache.get(key) {
                // Re-install the wire-key alias: it may have been
                // LRU-evicted independently of the content entry, and
                // this request never reaches a worker to restore it.
                if let Some(wk) = wire_key {
                    self.ctx.cache.put(wk, hit.clone());
                }
                let total_ms = crate::util::ms(submitted.elapsed());
                span.flags |= flag::CACHE_HIT;
                let mut resp = self.cache_hit_response(id, &hit, total_ms);
                resp.span = Some(span);
                reply.send(resp);
                return Ok(());
            }
            Some(key)
        } else {
            None
        };

        // One fair-share computation (one scheduler lock) serves every
        // port's view on this hot path.
        let share = self
            .runtime
            .scheduler
            .fair_share(self.runtime.workers, &self.ports[0].source().key);
        let views: Vec<PoolView> =
            self.ports.iter().map(|p| p.view_with(share)).collect();
        let budget_ms = slo.deadline_ms();
        let decision = self.selector.choose(&self.ctx.predictor, &views, &slo, budget_ms);

        let port = match decision {
            Decision::Route { pool, .. } => pool,
            Decision::Shed { best_ms } => {
                self.count_rejected();
                reply.disarm();
                let any_room = views.iter().any(|v| v.queued < v.capacity);
                let err = match (budget_ms, any_room) {
                    (Some(deadline_ms), true) => {
                        self.ctx.shed_predicted.fetch_add(1, Ordering::Relaxed);
                        span.flags |= flag::SHED_PREDICTED;
                        SubmitError::Shed {
                            predicted_ms: best_ms,
                            deadline_ms,
                        }
                    }
                    _ => {
                        span.flags |= flag::REJECTED;
                        SubmitError::Overloaded
                    }
                };
                self.stats.obs.record_shed(&span);
                if let Some(sup) = SHED_LOG.allow() {
                    crate::warn!(
                        "registry",
                        "shed request {id} on '{}': {err}{}",
                        self.model,
                        suppressed_note(sup)
                    );
                }
                return Err((err, Some(image)));
            }
        };

        span.set(Stage::Admitted, self.stats.obs.now_ns());
        let req = Request {
            id,
            image,
            submitted,
            slo,
            cache_key,
            wire_key: wire_key.filter(|_| cache_key.is_some()),
            reply,
            span,
        };
        match self.ports[port].admit(req) {
            Ok(_) => Ok(()),
            Err(RouteError::Overloaded(r)) => {
                self.count_rejected();
                r.reply.disarm();
                let mut s = r.span;
                s.flags |= flag::REJECTED;
                self.stats.obs.record_shed(&s);
                if let Some(sup) = SHED_LOG.allow() {
                    crate::warn!(
                        "registry",
                        "rejected request {id} on '{}': queue full{}",
                        self.model,
                        suppressed_note(sup)
                    );
                }
                Err((SubmitError::Overloaded, Some(r.image)))
            }
            // Retired mid-swap: the caller re-resolves the model and
            // retries on the fresh generation with the reclaimed image
            // (no rejection counted — the request was never refused,
            // just redirected).
            Err(RouteError::Closed(r)) => {
                r.reply.disarm();
                Err((SubmitError::Closed, Some(r.image)))
            }
        }
    }

    /// Per-queue policy snapshot rows (`{"cmd":"policy"}`).  `workers`
    /// reports this queue's current fair share of the shared fleet —
    /// the drain-parallelism bound the selector's prediction uses.
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        self.ports
            .iter()
            .map(|p| {
                let view = p.view(self.runtime.workers);
                PoolSnapshot {
                    engine: p.kind().as_str(),
                    workers: view.workers,
                    queued: view.queued,
                    capacity: view.capacity,
                    predicted_ms: self.selector.predict_ms(&self.ctx.predictor, &view),
                    samples: self.ctx.predictor.samples(p.kind()),
                }
            })
            .collect()
    }

    /// Close this generation's queues (graceful: admitted requests
    /// still drain through the runtime workers on the *old* weights),
    /// wait for the drain condition (closed + empty + zero in-flight
    /// batches), and deregister the queues from the scheduler.
    /// Idempotent — the second caller returns immediately.  In-flight
    /// requests are all answered before this returns.
    pub(super) fn retire(&self) {
        if self.retired.swap(true, Ordering::AcqRel) {
            return;
        }
        for p in &self.ports {
            p.close();
        }
        for p in &self.ports {
            self.runtime.scheduler.wait_drained(&p.source().key);
        }
    }
}

impl Drop for Generation {
    /// Backstop for generations dropped without an explicit retire (the
    /// last lease on a reloaded-away generation going out of scope):
    /// close + drain + deregister so worker replica caches release this
    /// generation's engines and its pooled tensors retire exactly when
    /// the last lease ends, never before a queued request was answered.
    /// Runtime workers never hold a lease, so this wait cannot deadlock
    /// on itself.
    fn drop(&mut self) {
        self.retire();
    }
}
