//! One immutable serving generation of one model: engine pools, worker
//! threads, tensor arena, and per-generation policy state.
//!
//! A generation is built *cold* (load manifest → spawn workers → warm
//! engines, failing fast on any build error) and only then published by
//! the registry, so requests never observe a half-warmed model.  After a
//! hot reload retires it, the generation drains gracefully:
//!
//! * its queues close (graceful: residual items still pop), so every
//!   request already admitted is served by the *old* weights;
//! * worker threads exit — dropping their engines — only after the
//!   drain, and [`Generation::retire`] joins them;
//! * the `Generation` itself (arena handle, policy ctx, manifest) is
//!   kept alive by `Arc` until the last [`super::GenerationLease`]
//!   drops, and `Drop` re-runs `retire` as an idempotent backstop.
//!
//! Policy state is **per generation** on purpose: a reload means new
//! weights, and a response cache or latency EWMA carried across weights
//! would serve stale classifications / stale predictions.  Cache keys
//! therefore can never cross models *or* generations.

use anyhow::{bail, Context, Result};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::Config;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::router::{RouteError, Router};
use crate::coordinator::worker::{self, SharedStats, WorkerReport, WorkerSeat};
use crate::coordinator::{Request, Response, SubmitError};
use crate::engine::EngineKind;
use crate::policy::{
    self, image_key, Decision, PolicyCtx, PoolSnapshot, PoolView, Selector, Slo,
};
use crate::runtime::Manifest;
use crate::tensor::{PooledTensor, TensorPool};

use super::ModelCounters;

/// One engine pool: a router over per-worker bounded queues.
struct EnginePool {
    kind: EngineKind,
    router: Router<Request>,
    workers: usize,
}

impl EnginePool {
    /// Admission-time snapshot for the selector / introspection.
    fn view(&self) -> PoolView {
        PoolView {
            kind: self.kind,
            queued: self.router.queued(),
            workers: self.workers,
            capacity: self.router.capacity(),
        }
    }
}

/// Batch sizes a given engine kind has compiled artifacts for.
fn supported_sizes(kind: EngineKind, manifest: &Manifest) -> Vec<usize> {
    match kind {
        EngineKind::AclStaged | EngineKind::Sim => manifest.batch_sizes.clone(),
        EngineKind::AclFused => manifest.full.keys().copied().collect(),
        _ => vec![1],
    }
}

/// One warmed serving generation of one model (see module docs).
pub struct Generation {
    model: Arc<str>,
    generation: u64,
    input_hw: usize,
    pools: Vec<EnginePool>,
    /// Taken (not just borrowed) by `retire`, so shutdown and the
    /// drop-backstop can both run without double-joining.
    handles: Mutex<Vec<JoinHandle<WorkerReport>>>,
    selector: Selector,
    ctx: Arc<PolicyCtx>,
    arena: TensorPool,
    /// Process-wide aggregates (survive reloads; shared across models).
    stats: Arc<SharedStats>,
    /// Per-model counters (survive reloads; shared across generations).
    counters: Arc<ModelCounters>,
    /// Wall time spent building + warming every worker's engine.
    warm_ms: f64,
}

impl Generation {
    /// Load the manifest at `artifacts`, spawn + warm all worker pools.
    /// Returns only when every worker is ready to serve — or fails fast
    /// if any worker can't build its engine.  Nothing is published until
    /// this returns, which is what makes reloads atomic.
    pub(super) fn start(
        model: Arc<str>,
        generation: u64,
        artifacts: &std::path::Path,
        cfg: &Config,
        stats: Arc<SharedStats>,
        counters: Arc<ModelCounters>,
    ) -> Result<Generation> {
        let t0 = Instant::now();
        let manifest = Manifest::load(artifacts)
            .with_context(|| format!("loading manifest for model '{model}'"))?;

        // With `cfg.policy.adaptive`, two pools come up — the configured
        // engine (quality path) plus the int8 quant path — and the SLO
        // selector routes between them per request.
        let specs: Vec<(EngineKind, usize)> = if cfg.policy.adaptive {
            vec![
                (cfg.engine, cfg.workers),
                (EngineKind::Quant, cfg.policy.quant_workers),
            ]
        } else {
            vec![(cfg.engine, cfg.workers)]
        };

        let ctx = Arc::new(PolicyCtx::new(
            cfg.policy.ewma_alpha,
            cfg.policy.cache_capacity,
        ));
        for &(kind, _) in &specs {
            ctx.predictor.seed(kind, 1, policy::default_prior_ms(kind));
        }

        let (ready_tx, ready_rx) = mpsc::channel();

        // Tensor arena for this model's request path: decode buffers plus
        // one batch buffer per compiled batch size, shelved at startup so
        // the steady state never allocates pixels.
        let input_len = manifest.input_hw * manifest.input_hw * 3;
        let arena = TensorPool::with_mode(cfg.pool.enabled, cfg.pool.per_class_cap);
        arena.prealloc(input_len, cfg.queue_capacity);

        let mut pools = Vec::with_capacity(specs.len());
        let mut handles = Vec::new();
        let mut worker_index = 0usize;
        for (pool_index, &(kind, n_workers)) in specs.iter().enumerate() {
            let supported = supported_sizes(kind, &manifest);
            for &b in supported.iter().filter(|&&b| b <= cfg.max_batch) {
                arena.prealloc(b * input_len, n_workers);
            }
            let policy = BatchPolicy::new(cfg.max_batch, cfg.batch_timeout, &supported);
            let queues: Vec<Arc<BoundedQueue<Request>>> = (0..n_workers)
                .map(|_| Arc::new(BoundedQueue::new(cfg.queue_capacity)))
                .collect();
            for q in &queues {
                handles.push(worker::spawn_worker(
                    WorkerSeat {
                        index: worker_index,
                        kind,
                        model: model.clone(),
                        manifest: manifest.clone(),
                        queue: q.clone(),
                        policy: policy.clone(),
                        stats: stats.clone(),
                        counters: counters.clone(),
                        ctx: ctx.clone(),
                        arena: arena.clone(),
                        // Only the quality pool (specs[0]) fills the cache
                        // so hits never downgrade accuracy to the int8
                        // path.
                        fill_cache: pool_index == 0,
                    },
                    ready_tx.clone(),
                ));
                worker_index += 1;
            }
            pools.push(EnginePool {
                kind,
                router: Router::new(queues),
                workers: n_workers,
            });
        }
        drop(ready_tx);

        // Wait for all workers (fail fast on any engine build error).
        for _ in 0..worker_index {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    for p in &pools {
                        p.router.close_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    bail!("model '{model}': worker failed to start: {e:#}");
                }
                Err(_) => {
                    bail!("model '{model}': worker exited before signalling readiness")
                }
            }
        }

        let warm_ms = crate::util::ms(t0.elapsed());
        crate::info!(
            "registry",
            "model '{}' gen {} ready in {:.0}ms: pools={:?} max_batch={}",
            model,
            generation,
            warm_ms,
            pools
                .iter()
                .map(|p| format!("{}x{}", p.kind.as_str(), p.workers))
                .collect::<Vec<_>>(),
            cfg.max_batch,
        );

        Ok(Generation {
            model,
            generation,
            input_hw: manifest.input_hw,
            pools,
            handles: Mutex::new(handles),
            selector: Selector::new(cfg.policy.margin, 1),
            ctx,
            arena,
            stats,
            counters,
            warm_ms,
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Monotonic per-model generation number (1 = first load).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn input_hw(&self) -> usize {
        self.input_hw
    }

    /// Wall time spent building + warming this generation's engines.
    pub fn warm_ms(&self) -> f64 {
        self.warm_ms
    }

    /// This model's tensor arena (decode buffers lease from here).
    pub fn arena(&self) -> TensorPool {
        self.arena.clone()
    }

    /// This generation's policy state (per-model predictor + cache).
    pub fn ctx(&self) -> &Arc<PolicyCtx> {
        &self.ctx
    }

    /// Requests queued across this generation's pools.
    pub fn queued(&self) -> usize {
        self.pools.iter().map(|p| p.router.queued()).sum()
    }

    /// Reject wrong-shaped inputs before they touch queues or the arena.
    fn check_shape(&self, shape: &[usize]) -> Result<(), SubmitError> {
        let want = [self.input_hw, self.input_hw, 3];
        if shape != want {
            return Err(SubmitError::BadInput(format!(
                "expected shape {want:?}, got {shape:?}"
            )));
        }
        Ok(())
    }

    fn count_rejected(&self) {
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        self.counters.rejected.fetch_add(1, Ordering::Relaxed);
    }

    fn cache_hit_response(&self, id: u64, hit: &policy::CachedResult, total_ms: f64) -> Response {
        let mut r = Response::cache_hit(id, hit, total_ms);
        r.model = self.model.clone();
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.counters.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.latency.lock().unwrap().record_ms(total_ms);
        r
    }

    /// Response-cache lookup by an externally computed key — the
    /// server's wire-key fast path.  A hit means the caller can skip
    /// image decode entirely; a miss is not counted against the cache
    /// (the post-decode content-key lookup counts once per request).
    /// Keys live in this generation's cache only, so a hit can never
    /// cross models or weight generations.
    pub fn cached_response(&self, key: u64) -> Option<Response> {
        if !self.ctx.cache.enabled() {
            return None;
        }
        let t0 = Instant::now();
        let hit = self.ctx.cache.peek(key)?;
        // Measured, like the content-key hit path — cache hits are real
        // requests with (near-zero) real latency.
        let total_ms = crate::util::ms(t0.elapsed());
        Some(self.cache_hit_response(0, &hit, total_ms))
    }

    /// Zero-copy submission onto this generation: the image already
    /// lives in a pooled lease (ideally from [`Generation::arena`]).
    /// The cache is consulted first (a hit replies immediately without
    /// touching an engine); otherwise the selector routes to the best
    /// pool predicted to meet the deadline, or sheds.  `wire_key`
    /// optionally keys the response cache on the raw request bytes so a
    /// repeat of the same wire spec skips decode entirely next time.
    pub fn submit_pooled(
        &self,
        id: u64,
        image: PooledTensor,
        slo: Slo,
        wire_key: Option<u64>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.check_shape(image.shape())?;
        let submitted = Instant::now();

        // Response cache: repeated frames skip inference entirely.
        let cache_key = if self.ctx.cache.enabled() {
            let key = image_key(image.data());
            if let Some(hit) = self.ctx.cache.get(key) {
                // Re-install the wire-key alias: it may have been
                // LRU-evicted independently of the content entry, and
                // this request never reaches a worker to restore it.
                if let Some(wk) = wire_key {
                    self.ctx.cache.put(wk, hit.clone());
                }
                let (tx, rx) = mpsc::channel();
                let total_ms = crate::util::ms(submitted.elapsed());
                let _ = tx.send(self.cache_hit_response(id, &hit, total_ms));
                return Ok(rx);
            }
            Some(key)
        } else {
            None
        };

        let views: Vec<PoolView> = self.pools.iter().map(EnginePool::view).collect();
        let budget_ms = slo.deadline_ms();
        let decision = self
            .selector
            .choose(&self.ctx.predictor, &views, &slo, budget_ms);

        let pool = match decision {
            Decision::Route { pool, .. } => pool,
            Decision::Shed { best_ms } => {
                self.count_rejected();
                let any_room = views.iter().any(|v| v.queued < v.capacity);
                return Err(match (budget_ms, any_room) {
                    (Some(deadline_ms), true) => {
                        self.ctx.shed_predicted.fetch_add(1, Ordering::Relaxed);
                        SubmitError::Shed {
                            predicted_ms: best_ms,
                            deadline_ms,
                        }
                    }
                    _ => SubmitError::Overloaded,
                });
            }
        };

        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            image,
            submitted,
            slo,
            cache_key,
            wire_key: wire_key.filter(|_| cache_key.is_some()),
            reply: tx,
        };
        match self.pools[pool].router.route(req) {
            Ok(_) => Ok(rx),
            Err(RouteError::Overloaded(_)) => {
                self.count_rejected();
                Err(SubmitError::Overloaded)
            }
            // Retired mid-swap: the caller re-resolves the model and
            // retries on the fresh generation (no rejection counted —
            // the request was never refused, just redirected).
            Err(RouteError::Closed(_)) => Err(SubmitError::Closed),
        }
    }

    /// Per-pool policy snapshot rows (`{"cmd":"policy"}`).
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        self.pools
            .iter()
            .map(|p| {
                let view = p.view();
                PoolSnapshot {
                    engine: p.kind.as_str(),
                    workers: p.workers,
                    queued: view.queued,
                    capacity: view.capacity,
                    predicted_ms: self.selector.predict_ms(&self.ctx.predictor, &view),
                    samples: self.ctx.predictor.samples(p.kind),
                }
            })
            .collect()
    }

    /// Close queues (graceful: admitted requests still drain) and join
    /// every worker.  Idempotent — the second caller joins nothing.
    /// In-flight requests are all answered before this returns, because
    /// workers only exit once their queue is closed *and* empty.
    pub(super) fn retire(&self) -> Vec<WorkerReport> {
        for p in &self.pools {
            p.router.close_all();
        }
        let handles: Vec<JoinHandle<WorkerReport>> =
            std::mem::take(&mut *self.handles.lock().unwrap());
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    }
}

impl Drop for Generation {
    /// Backstop for generations dropped without an explicit retire (the
    /// last lease on a reloaded-away generation going out of scope):
    /// close + drain + join so engines and pooled tensors are released
    /// exactly when the last lease ends, never before a queued request
    /// was answered.  Workers never hold a lease on their own
    /// generation, so this join cannot be a self-join.
    fn drop(&mut self) {
        let _ = self.retire();
    }
}
