//! Multi-model serving registry (DESIGN.md §8).
//!
//! The runtime used to be hard-wired to exactly one
//! `artifacts/manifest.json`.  The registry lifts that to N named models
//! — each its own manifest + weights dir, discovered from a
//! `models.json` index or repeated `--model name=path` flags — with:
//!
//! * **lazy per-model generations**: a model's [`Generation`] (scheduled
//!   queues + arena + policy state — no threads; the shared worker
//!   runtime executes everything) is built on first request, or eagerly
//!   with `registry.preload`;
//! * **atomic hot reload**: [`ModelRegistry::reload`] builds and
//!   validates a *new* generation from disk, then swaps one `Arc` —
//!   requests resolving the model concurrently get either the old or
//!   the new generation, never an unproven one;
//! * **RAII generation leases**: [`GenerationLease`] (a wrapped `Arc`)
//!   pins a generation for the duration of a request, so a retired
//!   generation's pooled tensors drop only after its last lease ends
//!   and its queues have drained — in-flight requests always finish on
//!   the generation that admitted them;
//! * **structural policy namespacing**: each generation owns its own
//!   predictor + response cache, so a cache hit can never cross models
//!   (content hashes collide across models by construction — same
//!   pixels, different weights) nor weight generations.
//!
//! Unknown model names are a structured reject
//! ([`SubmitError::UnknownModel`]), never a silent fallback to the
//! default model.

pub mod generation;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::ops::Deref;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::{Config, RegistryConfig};
use crate::coordinator::scheduler::RuntimeHandle;
use crate::coordinator::worker::SharedStats;
use crate::coordinator::SubmitError;

pub use generation::Generation;

/// Per-model serving counters.  Owned by the [`ModelEntry`], not the
/// generation, so they survive hot reloads.
#[derive(Debug, Default)]
pub struct ModelCounters {
    pub completed: AtomicU64,
    pub images: AtomicU64,
    pub rejected: AtomicU64,
    /// Replica constructions served from a validated AOT snapshot
    /// (probe builds and worker builds alike; DESIGN.md §11).
    pub snapshot_hits: AtomicU64,
    /// Cold builds that ran with snapshots enabled but none available
    /// (missing, stale, corrupt, or version-skewed `.zsnap`).
    pub snapshot_misses: AtomicU64,
    /// Validated snapshots whose engine construction still failed —
    /// each one fell back to a cold build (never a serving error).
    pub snapshot_fallbacks: AtomicU64,
    /// Replicas pre-built by the predictive warm-up path before any
    /// batch of theirs was picked.
    pub prefetch_builds: AtomicU64,
}

/// RAII guard pinning one model generation for the duration of a
/// request.  Holding the lease guarantees the generation's arena and
/// policy state outlive the request even if the model is hot-reloaded
/// concurrently; dropping the last lease of a retired generation
/// releases all of it (after the queue drain — see [`Generation`]'s
/// drop docs).
pub struct GenerationLease {
    inner: Arc<Generation>,
}

impl Deref for GenerationLease {
    type Target = Generation;
    fn deref(&self) -> &Generation {
        &self.inner
    }
}

/// What a completed [`ModelRegistry::reload`] reports.
#[derive(Debug, Clone)]
pub struct ReloadReport {
    pub model: String,
    /// The new generation number now serving.
    pub generation: u64,
    /// Wall time spent building + validating the new generation (the
    /// old one kept serving throughout).
    pub warm_ms: f64,
    /// `false` when the reload short-circuited because the artifact
    /// content hash was unchanged: the generation number was bumped to
    /// acknowledge the request, but no probe build ran and the serving
    /// generation (weights, queues, caches) is untouched.
    pub rebuilt: bool,
}

/// One registered model: artifact location, lifetime counters, and the
/// current generation slot.
pub struct ModelEntry {
    name: Arc<str>,
    artifacts: PathBuf,
    counters: Arc<ModelCounters>,
    /// Generation numbers issued so far (1 = first load).
    generations: AtomicU64,
    /// The published generation; `None` until first use (lazy build).
    current: RwLock<Option<Arc<Generation>>>,
    /// Serializes builds and reloads for this model; never held while
    /// serving (reads of `current` don't take it), so the old
    /// generation keeps serving during a warm-up.
    build_lock: Mutex<()>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn artifacts(&self) -> &std::path::Path {
        &self.artifacts
    }

    pub fn counters(&self) -> &ModelCounters {
        &self.counters
    }

    /// Generation currently published (0 = never loaded).
    pub fn generation_number(&self) -> u64 {
        self.generations.load(Ordering::Relaxed)
    }

    pub fn loaded(&self) -> bool {
        self.current.read().unwrap().is_some()
    }

    fn current(&self) -> Option<Arc<Generation>> {
        self.current.read().unwrap().clone()
    }
}

/// The model table: name -> entry, plus the config and runtime handle
/// needed to build generations on demand.
pub struct ModelRegistry {
    cfg: Config,
    entries: BTreeMap<String, Arc<ModelEntry>>,
    default_model: String,
    stats: Arc<SharedStats>,
    /// Handle on the shared worker runtime: generations register their
    /// queues here; nobody spawns threads below this point.
    runtime: RuntimeHandle,
    /// Background drain waiters spawned by reload() — joined at
    /// shutdown so no retired generation is still draining when
    /// shutdown returns.
    retire_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ModelRegistry {
    /// Build the table from config.  No generations are constructed here
    /// (see [`ModelRegistry::preload`] / lazy resolution); this only
    /// validates the shape of the registry itself.
    pub fn new(
        cfg: Config,
        stats: Arc<SharedStats>,
        runtime: RuntimeHandle,
    ) -> Result<ModelRegistry> {
        let specs: Vec<(String, PathBuf)> = if cfg.registry.models.is_empty() {
            vec![(
                RegistryConfig::SINGLE_MODEL.to_string(),
                cfg.artifacts.clone(),
            )]
        } else {
            cfg.registry.models.clone()
        };
        let default_model = if cfg.registry.models.is_empty() {
            RegistryConfig::SINGLE_MODEL.to_string()
        } else {
            cfg.registry.effective_default().to_string()
        };

        let mut entries = BTreeMap::new();
        for (name, artifacts) in specs {
            let entry = Arc::new(ModelEntry {
                name: Arc::from(name.as_str()),
                artifacts,
                counters: Arc::new(ModelCounters::default()),
                generations: AtomicU64::new(0),
                current: RwLock::new(None),
                build_lock: Mutex::new(()),
            });
            if entries.insert(name.clone(), entry).is_some() {
                bail!("duplicate model name '{name}' in registry");
            }
        }
        if !entries.contains_key(&default_model) {
            bail!("default model '{default_model}' is not registered");
        }

        Ok(ModelRegistry {
            cfg,
            entries,
            default_model,
            stats,
            runtime,
            retire_threads: Mutex::new(Vec::new()),
        })
    }

    pub fn default_model(&self) -> &str {
        &self.default_model
    }

    /// The config generations are built from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Registered model names, in table order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|k| k.as_str()).collect()
    }

    pub fn entry(&self, name: &str) -> Option<&Arc<ModelEntry>> {
        self.entries.get(name)
    }

    pub fn entries(&self) -> impl Iterator<Item = &Arc<ModelEntry>> {
        self.entries.values()
    }

    /// Resolve a model name (`None` = default) to a leased generation,
    /// building it on first use.  Unknown names are a structured reject
    /// — never a fallback to the default model.
    pub fn resolve(&self, model: Option<&str>) -> Result<GenerationLease, SubmitError> {
        let name = model.unwrap_or(&self.default_model);
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| SubmitError::UnknownModel(name.to_string()))?;
        if let Some(g) = entry.current() {
            return Ok(GenerationLease { inner: g });
        }
        self.build_current(entry).map_err(|e| SubmitError::ModelUnavailable {
            model: name.to_string(),
            reason: format!("{e:#}"),
        })
    }

    /// First-use build under the entry's build lock (double-checked so
    /// concurrent first requests build once and share the result).
    fn build_current(&self, entry: &Arc<ModelEntry>) -> Result<GenerationLease> {
        let _build = entry.build_lock.lock().unwrap();
        if let Some(g) = entry.current() {
            return Ok(GenerationLease { inner: g });
        }
        let gen_no = entry.generations.fetch_add(1, Ordering::Relaxed) + 1;
        let built = Arc::new(Generation::start(
            entry.name.clone(),
            gen_no,
            &entry.artifacts,
            &self.cfg,
            self.runtime.clone(),
            self.stats.clone(),
            entry.counters.clone(),
        )?);
        *entry.current.write().unwrap() = Some(built.clone());
        Ok(GenerationLease { inner: built })
    }

    /// Eagerly build every registered model's generation (startup
    /// preload, or just the default model when `default_only`).
    pub fn preload(&self, default_only: bool) -> Result<()> {
        if default_only {
            self.resolve(None)
                .map_err(|e| anyhow::anyhow!("preloading default model: {e}"))?;
            return Ok(());
        }
        for name in self.entries.keys() {
            self.resolve(Some(name))
                .map_err(|e| anyhow::anyhow!("preloading model '{name}': {e}"))?;
        }
        Ok(())
    }

    /// Atomic hot reload: build + validate a fresh generation from the
    /// model's artifacts dir, publish it with one `Arc` swap, and drain
    /// the old generation on a background waiter.  In-flight requests
    /// finish on the old generation; its pooled tensors (and the
    /// workers' cached engine replicas for it) are released only once
    /// its queues have drained and the last lease ends.  No worker
    /// threads are spawned: the same fixed runtime serves old and new
    /// queues side by side during the drain.  On build failure the old
    /// generation keeps serving untouched.
    pub fn reload(&self, model: Option<&str>) -> Result<ReloadReport> {
        let name = model.unwrap_or(&self.default_model);
        let entry = self
            .entries
            .get(name)
            .with_context(|| format!("unknown model '{name}'"))?;

        let _build = entry.build_lock.lock().unwrap();

        // No-op reload short-circuit: if the artifacts on disk hash to
        // exactly what the serving generation was built from, a rebuild
        // would produce byte-identical weights — skip the probe build
        // entirely and acknowledge with a generation-number bump.  The
        // serving generation (queues, caches, predictor) is untouched,
        // so a fleet-wide `reload` sweep against unchanged models costs
        // three file reads per model instead of a build + warm-up.
        // Hash errors (e.g. artifacts deleted mid-flight) fall through
        // to the build path, which reports the real failure.
        if let Some(current) = entry.current() {
            if let Ok(live) = crate::runtime::artifact_content_hash(&entry.artifacts) {
                if live == current.content_hash() {
                    let gen_no = entry.generations.fetch_add(1, Ordering::Relaxed) + 1;
                    crate::info!(
                        "registry",
                        "reload '{name}': artifacts unchanged (hash {live:016x}); \
                         gen {gen_no} is a no-op bump"
                    );
                    return Ok(ReloadReport {
                        model: name.to_string(),
                        generation: gen_no,
                        warm_ms: 0.0,
                        rebuilt: false,
                    });
                }
            }
        }

        let gen_no = entry.generations.fetch_add(1, Ordering::Relaxed) + 1;
        let fresh = Arc::new(Generation::start(
            entry.name.clone(),
            gen_no,
            &entry.artifacts,
            &self.cfg,
            self.runtime.clone(),
            self.stats.clone(),
            entry.counters.clone(),
        )?);
        let warm_ms = fresh.warm_ms();
        let old = entry.current.write().unwrap().replace(fresh);

        if let Some(old) = old {
            // Drain off the caller's thread: retire() blocks until the
            // old queues are closed, empty, and batch-free (every
            // admitted request answered by the old weights).  The
            // handle is kept so shutdown() can join the waiter.
            let handle = std::thread::Builder::new()
                .name(format!("zuluko-retire-{name}"))
                .spawn(move || {
                    old.retire();
                    drop(old);
                })
                .expect("spawn retire waiter");
            self.retire_threads.lock().unwrap().push(handle);
        }

        Ok(ReloadReport {
            model: name.to_string(),
            generation: gen_no,
            warm_ms,
            rebuilt: true,
        })
    }

    /// Retire every generation (close queues, wait for the runtime to
    /// drain them, deregister) — including the background drains of
    /// reload-retired generations.  When this returns, every admitted
    /// request has been answered and no generation is still draining;
    /// the caller may then shut the shared runtime down.
    pub fn shutdown(&self) {
        for entry in self.entries.values() {
            let taken = entry.current.write().unwrap().take();
            if let Some(g) = taken {
                g.retire();
                // `g` may still be leased elsewhere; dropping our Arc is
                // enough — retire() already drained the queues.
            }
        }
        let drains: Vec<_> = std::mem::take(&mut *self.retire_threads.lock().unwrap());
        for h in drains {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::Scheduler;
    use crate::engine::EngineKind;
    use std::time::Duration;

    fn synth_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zuluko_registry_unit_{tag}_{}",
            std::process::id()
        ));
        crate::testkit::manifest::write_synthetic(&dir, tag, 100, 227, &[1, 2])
            .unwrap();
        dir
    }

    fn sim_cfg(models: &[(&str, PathBuf)]) -> Config {
        let mut cfg = Config {
            engine: EngineKind::Sim,
            workers: 1,
            max_batch: 2,
            queue_capacity: 8,
            ..Config::default()
        };
        for (n, p) in models {
            cfg.registry.upsert(n, p.clone());
        }
        cfg
    }

    /// A runtime handle with no worker threads: registry unit tests
    /// never submit requests, and an empty queue drains trivially.
    fn idle_runtime() -> RuntimeHandle {
        RuntimeHandle {
            scheduler: Arc::new(Scheduler::new(Duration::from_millis(50))),
            workers: 1,
        }
    }

    fn registry(cfg: Config) -> ModelRegistry {
        ModelRegistry::new(cfg, Arc::new(SharedStats::default()), idle_runtime()).unwrap()
    }

    #[test]
    fn single_model_mode_registers_the_implicit_default() {
        let reg = registry(Config::default());
        assert_eq!(reg.default_model(), RegistryConfig::SINGLE_MODEL);
        assert_eq!(reg.names(), vec![RegistryConfig::SINGLE_MODEL]);
        assert!(!reg.entry(RegistryConfig::SINGLE_MODEL).unwrap().loaded());
    }

    #[test]
    fn unknown_model_is_a_structured_reject() {
        let reg = registry(sim_cfg(&[("a", synth_dir("a"))]));
        match reg.resolve(Some("nope")) {
            Err(SubmitError::UnknownModel(m)) => assert_eq!(m, "nope"),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
        // And never a silent fallback: the default model stays unloaded.
        assert!(!reg.entry("a").unwrap().loaded());
    }

    #[test]
    fn lazy_build_then_reload_bumps_generation() {
        let dir = synth_dir("lazyreload");
        let reg = registry(sim_cfg(&[("a", dir.clone())]));
        assert_eq!(reg.entry("a").unwrap().generation_number(), 0);
        let lease = reg.resolve(Some("a")).unwrap();
        assert_eq!(lease.generation(), 1);
        // Generation 1 registered exactly one queue (sim, non-adaptive).
        assert_eq!(reg.runtime.scheduler.queue_rows().len(), 1);
        // Change the artifacts so the reload is a *real* rebuild (an
        // unchanged dir would short-circuit — covered separately below).
        crate::testkit::manifest::write_synthetic(&dir, "a", 101, 227, &[1, 2])
            .unwrap();
        let report = reg.reload(Some("a")).unwrap();
        assert_eq!(report.generation, 2);
        assert!(report.rebuilt);
        // The old lease still works structurally (model name intact),
        // and the new resolution sees the new generation.
        assert_eq!(lease.model(), "a");
        let fresh = reg.resolve(Some("a")).unwrap();
        assert_eq!(fresh.generation(), 2);
        drop(lease);
        reg.shutdown();
        // Every queue drained + deregistered: the scheduler table is
        // empty — the drain condition replaced thread joins.
        assert_eq!(reg.runtime.scheduler.queue_rows().len(), 0);
    }

    #[test]
    fn noop_reload_short_circuits_without_a_probe_build() {
        let dir = synth_dir("noopreload");
        let reg = registry(sim_cfg(&[("a", dir.clone())]));
        let lease = reg.resolve(Some("a")).unwrap();
        assert_eq!(lease.generation(), 1);
        drop(lease);
        // Reload with byte-identical artifacts: the content hash
        // matches the serving generation, so no probe build runs — the
        // scheduler table still holds exactly generation 1's queue (a
        // rebuild would have registered gen 2's queue alongside it
        // while the old one drains).
        let report = reg.reload(Some("a")).unwrap();
        assert!(!report.rebuilt, "unchanged artifacts must not rebuild");
        assert_eq!(report.generation, 2, "the bump is still acknowledged");
        assert_eq!(report.warm_ms, 0.0);
        let rows = reg.runtime.scheduler.queue_rows();
        assert_eq!(rows.len(), 1, "no new queue: {rows:?}");
        assert_eq!(rows[0].generation, 1);
        // Serving continues on the original generation object.
        let lease = reg.resolve(Some("a")).unwrap();
        assert_eq!(lease.generation(), 1);
        // Touching the artifacts makes the next reload a real rebuild.
        crate::testkit::manifest::write_synthetic(&dir, "a", 102, 227, &[1, 2])
            .unwrap();
        let report = reg.reload(Some("a")).unwrap();
        assert!(report.rebuilt);
        assert_eq!(report.generation, 3);
        drop(lease);
        reg.shutdown();
    }

    #[test]
    fn unavailable_artifacts_fail_without_poisoning_the_entry() {
        let missing = std::env::temp_dir().join(format!(
            "zuluko_registry_missing_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&missing);
        let reg = registry(sim_cfg(&[("ghost", missing.clone())]));
        match reg.resolve(Some("ghost")) {
            Err(SubmitError::ModelUnavailable { model, .. }) => {
                assert_eq!(model, "ghost")
            }
            other => panic!("expected ModelUnavailable, got {:?}", other.map(|_| ())),
        }
        // Artifacts appear later -> the same entry builds fine.
        crate::testkit::manifest::write_synthetic(&missing, "ghost", 10, 227, &[1])
            .unwrap();
        let lease = reg.resolve(Some("ghost")).unwrap();
        assert_eq!(lease.generation(), 2, "failed build burned generation 1");
        drop(lease);
        reg.shutdown();
    }
}
