//! Multi-model serving registry (DESIGN.md §8).
//!
//! The runtime used to be hard-wired to exactly one
//! `artifacts/manifest.json`.  The registry lifts that to N named models
//! — each its own manifest + weights dir, discovered from a
//! `models.json` index or repeated `--model name=path` flags — with:
//!
//! * **lazy per-model engine pools**: a model's [`Generation`] (pools +
//!   warmed workers + arena + policy state) is built on first request,
//!   or eagerly with `registry.preload`;
//! * **atomic hot reload**: [`ModelRegistry::reload`] builds and warms a
//!   *new* generation from disk, then swaps one `Arc` — requests
//!   resolving the model concurrently get either the old or the new
//!   generation, never a half-warmed one;
//! * **RAII generation leases**: [`GenerationLease`] (a wrapped `Arc`)
//!   pins a generation for the duration of a request, so a retired
//!   generation's pooled tensors and engines drop only after its last
//!   lease ends and its queues have drained — in-flight requests always
//!   finish on the generation that admitted them;
//! * **structural policy namespacing**: each generation owns its own
//!   predictor + response cache, so a cache hit can never cross models
//!   (content hashes collide across models by construction — same
//!   pixels, different weights) nor weight generations.
//!
//! Unknown model names are a structured reject
//! ([`SubmitError::UnknownModel`]), never a silent fallback to the
//! default model.

pub mod generation;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::ops::Deref;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::{Config, RegistryConfig};
use crate::coordinator::worker::{SharedStats, WorkerReport};
use crate::coordinator::SubmitError;

pub use generation::Generation;

/// Per-model serving counters.  Owned by the [`ModelEntry`], not the
/// generation, so they survive hot reloads.
#[derive(Debug, Default)]
pub struct ModelCounters {
    pub completed: AtomicU64,
    pub images: AtomicU64,
    pub rejected: AtomicU64,
}

/// RAII guard pinning one model generation for the duration of a
/// request.  Holding the lease guarantees the generation's arena,
/// engines, and policy state outlive the request even if the model is
/// hot-reloaded concurrently; dropping the last lease of a retired
/// generation releases all of it (after the queue drain — see
/// [`Generation`]'s drop docs).
pub struct GenerationLease {
    inner: Arc<Generation>,
}

impl Deref for GenerationLease {
    type Target = Generation;
    fn deref(&self) -> &Generation {
        &self.inner
    }
}

/// What a completed [`ModelRegistry::reload`] reports.
#[derive(Debug, Clone)]
pub struct ReloadReport {
    pub model: String,
    /// The new generation number now serving.
    pub generation: u64,
    /// Wall time spent building + warming the new generation (the old
    /// one kept serving throughout).
    pub warm_ms: f64,
}

/// One registered model: artifact location, lifetime counters, and the
/// current generation slot.
pub struct ModelEntry {
    name: Arc<str>,
    artifacts: PathBuf,
    counters: Arc<ModelCounters>,
    /// Generation numbers issued so far (1 = first load).
    generations: AtomicU64,
    /// The published generation; `None` until first use (lazy build).
    current: RwLock<Option<Arc<Generation>>>,
    /// Serializes builds and reloads for this model; never held while
    /// serving (reads of `current` don't take it), so the old
    /// generation keeps serving during a warm-up.
    build_lock: Mutex<()>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn artifacts(&self) -> &std::path::Path {
        &self.artifacts
    }

    pub fn counters(&self) -> &ModelCounters {
        &self.counters
    }

    /// Generation currently published (0 = never loaded).
    pub fn generation_number(&self) -> u64 {
        self.generations.load(Ordering::Relaxed)
    }

    pub fn loaded(&self) -> bool {
        self.current.read().unwrap().is_some()
    }

    fn current(&self) -> Option<Arc<Generation>> {
        self.current.read().unwrap().clone()
    }
}

/// The model table: name -> entry, plus the config needed to build
/// generations on demand.
pub struct ModelRegistry {
    cfg: Config,
    entries: BTreeMap<String, Arc<ModelEntry>>,
    default_model: String,
    stats: Arc<SharedStats>,
    /// Worker reports from generations retired by hot reloads, folded
    /// into the shutdown report.
    retired: Arc<Mutex<Vec<WorkerReport>>>,
    /// The background drain threads reload() spawns — joined at
    /// shutdown so no retired generation is still draining (and no
    /// report is lost) when shutdown returns.
    retire_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ModelRegistry {
    /// Build the table from config.  No generations are constructed here
    /// (see [`ModelRegistry::preload`] / lazy resolution); this only
    /// validates the shape of the registry itself.
    pub fn new(cfg: Config, stats: Arc<SharedStats>) -> Result<ModelRegistry> {
        let specs: Vec<(String, PathBuf)> = if cfg.registry.models.is_empty() {
            vec![(
                RegistryConfig::SINGLE_MODEL.to_string(),
                cfg.artifacts.clone(),
            )]
        } else {
            cfg.registry.models.clone()
        };
        let default_model = if cfg.registry.models.is_empty() {
            RegistryConfig::SINGLE_MODEL.to_string()
        } else {
            cfg.registry.effective_default().to_string()
        };

        let mut entries = BTreeMap::new();
        for (name, artifacts) in specs {
            let entry = Arc::new(ModelEntry {
                name: Arc::from(name.as_str()),
                artifacts,
                counters: Arc::new(ModelCounters::default()),
                generations: AtomicU64::new(0),
                current: RwLock::new(None),
                build_lock: Mutex::new(()),
            });
            if entries.insert(name.clone(), entry).is_some() {
                bail!("duplicate model name '{name}' in registry");
            }
        }
        if !entries.contains_key(&default_model) {
            bail!("default model '{default_model}' is not registered");
        }

        Ok(ModelRegistry {
            cfg,
            entries,
            default_model,
            stats,
            retired: Arc::new(Mutex::new(Vec::new())),
            retire_threads: Mutex::new(Vec::new()),
        })
    }

    pub fn default_model(&self) -> &str {
        &self.default_model
    }

    /// The config generations are built from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Registered model names, in table order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|k| k.as_str()).collect()
    }

    pub fn entry(&self, name: &str) -> Option<&Arc<ModelEntry>> {
        self.entries.get(name)
    }

    pub fn entries(&self) -> impl Iterator<Item = &Arc<ModelEntry>> {
        self.entries.values()
    }

    /// Resolve a model name (`None` = default) to a leased generation,
    /// building it on first use.  Unknown names are a structured reject
    /// — never a fallback to the default model.
    pub fn resolve(&self, model: Option<&str>) -> Result<GenerationLease, SubmitError> {
        let name = model.unwrap_or(&self.default_model);
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| SubmitError::UnknownModel(name.to_string()))?;
        if let Some(g) = entry.current() {
            return Ok(GenerationLease { inner: g });
        }
        self.build_current(entry).map_err(|e| SubmitError::ModelUnavailable {
            model: name.to_string(),
            reason: format!("{e:#}"),
        })
    }

    /// First-use build under the entry's build lock (double-checked so
    /// concurrent first requests build once and share the result).
    fn build_current(&self, entry: &Arc<ModelEntry>) -> Result<GenerationLease> {
        let _build = entry.build_lock.lock().unwrap();
        if let Some(g) = entry.current() {
            return Ok(GenerationLease { inner: g });
        }
        let gen_no = entry.generations.fetch_add(1, Ordering::Relaxed) + 1;
        let built = Arc::new(Generation::start(
            entry.name.clone(),
            gen_no,
            &entry.artifacts,
            &self.cfg,
            self.stats.clone(),
            entry.counters.clone(),
        )?);
        *entry.current.write().unwrap() = Some(built.clone());
        Ok(GenerationLease { inner: built })
    }

    /// Eagerly build every registered model's pools (startup preload, or
    /// just the default model when `default_only`).
    pub fn preload(&self, default_only: bool) -> Result<()> {
        if default_only {
            self.resolve(None)
                .map_err(|e| anyhow::anyhow!("preloading default model: {e}"))?;
            return Ok(());
        }
        for name in self.entries.keys() {
            self.resolve(Some(name))
                .map_err(|e| anyhow::anyhow!("preloading model '{name}': {e}"))?;
        }
        Ok(())
    }

    /// Atomic hot reload: build + warm a fresh generation from the
    /// model's artifacts dir, publish it with one `Arc` swap, and drain
    /// the old generation on a background thread.  In-flight requests
    /// finish on the old generation; its engines and pooled tensors are
    /// released only once its queues have drained and the last lease
    /// ends.  On build failure the old generation keeps serving
    /// untouched.
    pub fn reload(&self, model: Option<&str>) -> Result<ReloadReport> {
        let name = model.unwrap_or(&self.default_model);
        let entry = self
            .entries
            .get(name)
            .with_context(|| format!("unknown model '{name}'"))?;

        let _build = entry.build_lock.lock().unwrap();
        let gen_no = entry.generations.fetch_add(1, Ordering::Relaxed) + 1;
        let fresh = Arc::new(Generation::start(
            entry.name.clone(),
            gen_no,
            &entry.artifacts,
            &self.cfg,
            self.stats.clone(),
            entry.counters.clone(),
        )?);
        let warm_ms = fresh.warm_ms();
        let old = entry.current.write().unwrap().replace(fresh);

        if let Some(old) = old {
            let sink = self.retired.clone();
            // Drain off the caller's thread: retire() blocks until the
            // old queues are empty (every admitted request answered).
            // The handle is kept so shutdown() can join the drain.
            let handle = std::thread::Builder::new()
                .name(format!("zuluko-retire-{name}"))
                .spawn(move || {
                    let reports = old.retire();
                    sink.lock().unwrap().extend(reports);
                    drop(old);
                })
                .expect("spawn retire thread");
            self.retire_threads.lock().unwrap().push(handle);
        }

        Ok(ReloadReport {
            model: name.to_string(),
            generation: gen_no,
            warm_ms,
        })
    }

    /// Close every generation, join every worker — including the
    /// background drains of reload-retired generations — and return all
    /// worker reports.  When this returns, every admitted request has
    /// been answered and no generation is still draining.
    pub fn shutdown(&self) -> Vec<WorkerReport> {
        let mut reports = Vec::new();
        for entry in self.entries.values() {
            let taken = entry.current.write().unwrap().take();
            if let Some(g) = taken {
                reports.extend(g.retire());
                // `g` may still be leased elsewhere; dropping our Arc is
                // enough — retire() already joined the workers.
            }
        }
        let drains: Vec<_> =
            std::mem::take(&mut *self.retire_threads.lock().unwrap());
        for h in drains {
            let _ = h.join();
        }
        reports.extend(self.retired.lock().unwrap().drain(..));
        reports
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineKind;

    fn synth_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zuluko_registry_unit_{tag}_{}",
            std::process::id()
        ));
        crate::testkit::manifest::write_synthetic(&dir, tag, 100, 227, &[1, 2])
            .unwrap();
        dir
    }

    fn sim_cfg(models: &[(&str, PathBuf)]) -> Config {
        let mut cfg = Config {
            engine: EngineKind::Sim,
            workers: 1,
            max_batch: 2,
            queue_capacity: 8,
            ..Config::default()
        };
        for (n, p) in models {
            cfg.registry.upsert(n, p.clone());
        }
        cfg
    }

    #[test]
    fn single_model_mode_registers_the_implicit_default() {
        let cfg = Config::default();
        let reg = ModelRegistry::new(cfg, Arc::new(SharedStats::default())).unwrap();
        assert_eq!(reg.default_model(), RegistryConfig::SINGLE_MODEL);
        assert_eq!(reg.names(), vec![RegistryConfig::SINGLE_MODEL]);
        assert!(!reg.entry(RegistryConfig::SINGLE_MODEL).unwrap().loaded());
    }

    #[test]
    fn unknown_model_is_a_structured_reject() {
        let cfg = sim_cfg(&[("a", synth_dir("a"))]);
        let reg = ModelRegistry::new(cfg, Arc::new(SharedStats::default())).unwrap();
        match reg.resolve(Some("nope")) {
            Err(SubmitError::UnknownModel(m)) => assert_eq!(m, "nope"),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
        // And never a silent fallback: the default model stays unloaded.
        assert!(!reg.entry("a").unwrap().loaded());
    }

    #[test]
    fn lazy_build_then_reload_bumps_generation() {
        let cfg = sim_cfg(&[("a", synth_dir("lazyreload"))]);
        let reg = ModelRegistry::new(cfg, Arc::new(SharedStats::default())).unwrap();
        assert_eq!(reg.entry("a").unwrap().generation_number(), 0);
        let lease = reg.resolve(Some("a")).unwrap();
        assert_eq!(lease.generation(), 1);
        let report = reg.reload(Some("a")).unwrap();
        assert_eq!(report.generation, 2);
        // The old lease still works structurally (model name intact),
        // and the new resolution sees the new generation.
        assert_eq!(lease.model(), "a");
        let fresh = reg.resolve(Some("a")).unwrap();
        assert_eq!(fresh.generation(), 2);
        drop(lease);
        let reports = reg.shutdown();
        // Exactly two single-worker generations served: the reloaded-away
        // gen 1 (drain joined by shutdown) and the live gen 2.
        assert_eq!(reports.len(), 2);
    }

    #[test]
    fn unavailable_artifacts_fail_without_poisoning_the_entry() {
        let missing = std::env::temp_dir().join(format!(
            "zuluko_registry_missing_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&missing);
        let cfg = sim_cfg(&[("ghost", missing.clone())]);
        let reg = ModelRegistry::new(cfg, Arc::new(SharedStats::default())).unwrap();
        match reg.resolve(Some("ghost")) {
            Err(SubmitError::ModelUnavailable { model, .. }) => {
                assert_eq!(model, "ghost")
            }
            other => panic!("expected ModelUnavailable, got {:?}", other.map(|_| ())),
        }
        // Artifacts appear later -> the same entry builds fine.
        crate::testkit::manifest::write_synthetic(&missing, "ghost", 10, 227, &[1])
            .unwrap();
        let lease = reg.resolve(Some("ghost")).unwrap();
        assert_eq!(lease.generation(), 2, "failed build burned generation 1");
        drop(lease);
        reg.shutdown();
    }
}
