//! Bench harness (criterion is not a dependency — DESIGN.md §Substitutions).
//!
//! Deliberately simple and honest: explicit warmup, fixed iteration
//! count, wall-clock per iteration, mean/median/p95/min/max + stddev, and
//! markdown table output so bench logs paste straight into EXPERIMENTS.md.
//!
//! ```ignore
//! let s = Bench::new("acl e2e").warmup(3).iters(30).run(|| { ... });
//! println!("{}", s.row());
//! ```

use std::time::{Duration, Instant};

/// Summary statistics for one measured case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub std_ms: f64,
    pub samples_ms: Vec<f64>,
}

impl Stats {
    pub fn from_samples(name: &str, samples_ms: Vec<f64>) -> Stats {
        let n = samples_ms.len().max(1);
        let mean = samples_ms.iter().sum::<f64>() / n as f64;
        let var = samples_ms
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f64>()
            / n as f64;
        let mut sorted = samples_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            name: name.to_string(),
            iters: samples_ms.len(),
            mean_ms: mean,
            median_ms: crate::util::percentile_sorted(&sorted, 50.0),
            p95_ms: crate::util::percentile_sorted(&sorted, 95.0),
            min_ms: sorted.first().copied().unwrap_or(0.0),
            max_ms: sorted.last().copied().unwrap_or(0.0),
            std_ms: var.sqrt(),
            samples_ms,
        }
    }

    /// Markdown table row: `| name | mean | median | p95 | min | max | n |`.
    pub fn row(&self) -> String {
        format!(
            "| {} | {:.2} | {:.2} | {:.2} | {:.2} | {:.2} | {} |",
            self.name,
            self.mean_ms,
            self.median_ms,
            self.p95_ms,
            self.min_ms,
            self.max_ms,
            self.iters
        )
    }

    pub const HEADER: &'static str =
        "| case | mean ms | median ms | p95 ms | min ms | max ms | n |\n|---|---|---|---|---|---|---|";
}

/// Builder for one benchmark case.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: 3,
            iters: 20,
        }
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.iters = n;
        self
    }

    /// Run `f` warmup+iters times, timing each measured call.
    pub fn run<F: FnMut()>(self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(crate::util::ms(t0.elapsed()));
        }
        Stats::from_samples(&self.name, samples)
    }

    /// Variant where the closure reports its own duration (e.g. the
    /// engine's internal exec time, excluding host prep).
    pub fn run_timed<F: FnMut() -> Duration>(self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            samples.push(crate::util::ms(f()));
        }
        Stats::from_samples(&self.name, samples)
    }
}

/// Print a comparison line: how much faster is `new` than `base`?
pub fn speedup_line(base: &Stats, new: &Stats) -> String {
    let s = base.mean_ms / new.mean_ms.max(1e-9);
    format!(
        "{} vs {}: {:.2}x ({:+.1}%)  [{:.2} ms -> {:.2} ms]",
        new.name,
        base.name,
        s,
        (s - 1.0) * 100.0,
        base.mean_ms,
        new.mean_ms
    )
}

/// Standard bench CLI: `--iters N --warmup N --quick` (quick = tiny run
/// for CI smoke).
pub struct BenchArgs {
    pub iters: usize,
    pub warmup: usize,
    pub quick: bool,
}

impl BenchArgs {
    pub fn from_env(default_iters: usize) -> BenchArgs {
        // `cargo bench -- --iters 50` passes args after the binary name;
        // also tolerate cargo's own `--bench` flag.
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut iters = default_iters;
        let mut warmup = 3;
        let mut quick = false;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--iters" if i + 1 < argv.len() => {
                    iters = argv[i + 1].parse().unwrap_or(default_iters);
                    i += 1;
                }
                "--warmup" if i + 1 < argv.len() => {
                    warmup = argv[i + 1].parse().unwrap_or(3);
                    i += 1;
                }
                "--quick" => quick = true,
                _ => {}
            }
            i += 1;
        }
        if quick {
            iters = iters.min(3);
            warmup = 1;
        }
        BenchArgs {
            iters,
            warmup,
            quick,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples("t", vec![1.0, 2.0, 3.0, 4.0, 10.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_ms - 4.0).abs() < 1e-9);
        assert_eq!(s.median_ms, 3.0);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 10.0);
        assert!(s.std_ms > 0.0);
    }

    #[test]
    fn bench_runs_expected_count() {
        let mut calls = 0;
        let s = Bench::new("count").warmup(2).iters(5).run(|| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn speedup_line_direction() {
        let base = Stats::from_samples("tf", vec![420.0]);
        let new = Stats::from_samples("acl", vec![320.0]);
        let line = speedup_line(&base, &new);
        assert!(line.contains("1.31x"), "{line}");
    }

    #[test]
    fn row_is_markdown() {
        let s = Stats::from_samples("x", vec![1.5]);
        assert!(s.row().starts_with("| x | 1.50 |"));
    }
}
