//! Model-level helpers shared by engines and the coordinator: shape math
//! over manifest entries and Fig 3 group classification of op kinds.
//!
//! The network *structure* lives in `artifacts/manifest.json` (written by
//! python/compile/model.py + graph.py); this module interprets it.

use crate::metrics::ledger::Group;
use crate::runtime::manifest::{Manifest, OpEntry};

/// Fig 3 classification of a primitive op kind.
pub fn group_of_kind(kind: &str) -> Group {
    match kind {
        "conv" | "conv_q8" | "relu" | "concat" => Group::Group1,
        "maxpool" | "gap" | "atten" | "softmax" => Group::Group2,
        "quantize" | "dequant_bias" => Group::Quant,
        _ => Group::Other,
    }
}

/// Elements of a batched shape (batch-less manifest shape + batch dim).
pub fn batched_elems(shape: &[usize], batch: usize) -> usize {
    batch * shape.iter().product::<usize>()
}

/// Bytes a tensor edge occupies in the framework registry.
pub fn edge_bytes(shape: &[usize], dtype: &str, batch: usize) -> usize {
    let per = match dtype {
        "i8" => 1,
        _ => 4,
    };
    batched_elems(shape, batch) * per
}

/// Total FLOPs of the fp32 network per image (2*MACs), from the op graph.
/// Used for the §Perf roofline discussion.
pub fn conv_flops(m: &Manifest) -> u64 {
    m.ops
        .iter()
        .filter(|o| o.kind == "conv")
        .map(|o| flops_of_conv(o))
        .sum()
}

fn flops_of_conv(o: &OpEntry) -> u64 {
    // out elems * (2 * K*K*Cin)
    let out: u64 = o.out_shape.iter().product::<usize>() as u64;
    let k = o.attr_k();
    let cin = *o.in_shapes[0].last().unwrap_or(&1) as u64;
    out * 2 * k * k * cin
}

impl OpEntry {
    /// Kernel size from the artifact name (manifest attrs are not carried
    /// into Rust; K is recoverable from shapes: conv weight is params[0]).
    fn attr_k(&self) -> u64 {
        // conv weights are named *_w / *_sw / *_e1w / *_e3w; their manifest
        // shape is (K, K, Cin, Cout) — but OpEntry only has names.  The
        // known K per site: conv1=7, expand3=3, everything else 1.
        if self.name == "conv1" {
            7
        } else if self.name.contains("expand3") {
            3
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_mapping_matches_paper() {
        assert_eq!(group_of_kind("conv"), Group::Group1);
        assert_eq!(group_of_kind("relu"), Group::Group1);
        assert_eq!(group_of_kind("concat"), Group::Group1);
        assert_eq!(group_of_kind("maxpool"), Group::Group2);
        assert_eq!(group_of_kind("softmax"), Group::Group2);
        assert_eq!(group_of_kind("quantize"), Group::Quant);
        assert_eq!(group_of_kind("dequant_bias"), Group::Quant);
    }

    #[test]
    fn edge_bytes_by_dtype() {
        assert_eq!(edge_bytes(&[2, 2, 3], "f32", 1), 48);
        assert_eq!(edge_bytes(&[2, 2, 3], "i8", 2), 24);
    }
}
