//! Adaptive engine selection: route each request to the best engine
//! variant that is predicted to meet its deadline, or shed it.
//!
//! Pools are ordered by result quality (fp32 ACL before the int8 quant
//! path — Fig 4 trades accuracy for speed).  The selector walks that
//! order and picks the first pool that (a) has queue room and (b) is
//! predicted — with a safety margin — to complete the request inside
//! its remaining budget.  Best-effort requests (no deadline) take the
//! first pool with room.  When nothing fits, the decision is an explicit
//! [`Decision::Shed`] carrying the best prediction, so the server can
//! send a structured `overloaded` rejection instead of letting a doomed
//! request burn engine time.
//!
//! Invariant (property-tested in rust/tests/policy_props.rs): the
//! selector never routes a deadlined request to a pool whose margin-
//! adjusted prediction exceeds the remaining budget while another pool's
//! fits.

use crate::engine::EngineKind;

use super::deadline::Slo;
use super::predictor::LatencyPredictor;

/// What the selector needs to know about one engine pool at admission
/// time.  Pools are presented in quality order (best first).
#[derive(Debug, Clone, Copy)]
pub struct PoolView {
    pub kind: EngineKind,
    /// Requests currently queued across the pool's workers.
    pub queued: usize,
    pub workers: usize,
    /// Total queue slots; `queued >= capacity` means the pool cannot
    /// admit.
    pub capacity: usize,
}

/// Routing decision for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Route to `pools[pool]`; `predicted_ms` is the margin-adjusted
    /// completion estimate used for admission.
    Route { pool: usize, predicted_ms: f64 },
    /// No pool can admit the request inside its budget.  `best_ms` is
    /// the smallest prediction seen (what the client would have gotten).
    Shed { best_ms: f64 },
}

/// Stateless selection policy over a shared [`LatencyPredictor`].
#[derive(Debug, Clone, Copy)]
pub struct Selector {
    /// Multiplier on predictions before comparing to the budget
    /// (headroom for EWMA mis-prediction; >= 1).
    pub margin: f64,
    /// Batch size assumed for prediction (the batcher's typical size).
    pub batch_hint: usize,
}

impl Selector {
    pub fn new(margin: f64, batch_hint: usize) -> Selector {
        Selector {
            margin: margin.max(1.0),
            batch_hint: batch_hint.max(1),
        }
    }

    /// Margin-adjusted completion prediction for one pool.
    pub fn predict_ms(&self, pred: &LatencyPredictor, pool: &PoolView) -> f64 {
        pred.completion_ms(pool.kind, pool.queued, pool.workers, self.batch_hint)
            * self.margin
    }

    /// Pick a pool for a request whose remaining budget is
    /// `remaining_ms` (`None` = best-effort).  `pools` must be in
    /// quality order.
    pub fn choose(
        &self,
        pred: &LatencyPredictor,
        pools: &[PoolView],
        slo: &Slo,
        remaining_ms: Option<f64>,
    ) -> Decision {
        let _ = slo; // priority shapes queue order, not engine choice
        let mut best_ms = f64::INFINITY;
        for (i, pool) in pools.iter().enumerate() {
            if pool.queued >= pool.capacity {
                continue;
            }
            let predicted_ms = self.predict_ms(pred, pool);
            best_ms = best_ms.min(predicted_ms);
            match remaining_ms {
                // Deadlined: first (highest-quality) pool that fits.
                Some(budget) => {
                    if predicted_ms <= budget {
                        return Decision::Route { pool: i, predicted_ms };
                    }
                }
                // Best-effort: first pool with room.
                None => return Decision::Route { pool: i, predicted_ms },
            }
        }
        Decision::Shed {
            best_ms: if best_ms.is_finite() { best_ms } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_pools(acl_queued: usize, quant_queued: usize) -> Vec<PoolView> {
        vec![
            PoolView {
                kind: EngineKind::AclStaged,
                queued: acl_queued,
                workers: 1,
                capacity: 8,
            },
            PoolView {
                kind: EngineKind::Quant,
                queued: quant_queued,
                workers: 1,
                capacity: 8,
            },
        ]
    }

    fn pred() -> LatencyPredictor {
        let p = LatencyPredictor::new(0.2);
        p.record(EngineKind::AclStaged, 1, 300.0);
        p.record(EngineKind::Quant, 1, 100.0);
        p
    }

    #[test]
    fn loose_deadline_prefers_quality() {
        let s = Selector::new(1.0, 1);
        let d = s.choose(&pred(), &two_pools(0, 0), &Slo::default(), Some(1000.0));
        assert!(matches!(d, Decision::Route { pool: 0, .. }), "{d:?}");
    }

    #[test]
    fn tight_deadline_falls_to_fast_engine() {
        let s = Selector::new(1.0, 1);
        let d = s.choose(&pred(), &two_pools(0, 0), &Slo::default(), Some(150.0));
        assert!(matches!(d, Decision::Route { pool: 1, .. }), "{d:?}");
    }

    #[test]
    fn impossible_deadline_sheds_with_best_prediction() {
        let s = Selector::new(1.0, 1);
        match s.choose(&pred(), &two_pools(0, 0), &Slo::default(), Some(50.0)) {
            Decision::Shed { best_ms } => assert!((best_ms - 100.0).abs() < 1e-9),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn backlog_shifts_the_choice() {
        // Quant with a deep backlog no longer fits; ACL does.
        let s = Selector::new(1.0, 1);
        let d = s.choose(&pred(), &two_pools(0, 7), &Slo::default(), Some(450.0));
        assert!(matches!(d, Decision::Route { pool: 0, .. }), "{d:?}");
    }

    #[test]
    fn full_pool_is_skipped_even_for_best_effort() {
        let mut pools = two_pools(0, 0);
        pools[0].queued = pools[0].capacity;
        let s = Selector::new(1.0, 1);
        let d = s.choose(&pred(), &pools, &Slo::default(), None);
        assert!(matches!(d, Decision::Route { pool: 1, .. }), "{d:?}");
    }

    #[test]
    fn everything_full_sheds() {
        let mut pools = two_pools(0, 0);
        pools[0].queued = pools[0].capacity;
        pools[1].queued = pools[1].capacity;
        let s = Selector::new(1.0, 1);
        assert!(matches!(
            s.choose(&pred(), &pools, &Slo::default(), None),
            Decision::Shed { .. }
        ));
    }

    #[test]
    fn margin_adds_headroom() {
        // 100ms prediction * 1.5 margin > 120ms budget -> shed.
        let s = Selector::new(1.5, 1);
        let pools = vec![PoolView {
            kind: EngineKind::Quant,
            queued: 0,
            workers: 1,
            capacity: 8,
        }];
        assert!(matches!(
            s.choose(&pred(), &pools, &Slo::default(), Some(120.0)),
            Decision::Shed { .. }
        ));
    }
}
