//! SLO-aware serving policy — the decision layer between the server and
//! the coordinator (DESIGN.md §7).
//!
//! The paper's thesis is that a from-scratch engine wins because it can
//! exploit workload knowledge a generic framework cannot.  This module
//! applies that idea above the engines: every request carries an
//! optional deadline and priority ([`deadline`]), an online EWMA
//! predictor tracks what each engine variant actually costs on this
//! hardware ([`predictor`]), an adaptive selector routes each request to
//! the cheapest variant that meets its SLO — or sheds it with a
//! structured rejection ([`selector`]) — and a content-addressed LRU
//! cache serves repeated frames without touching an engine at all
//! ([`cache`]).
//!
//! ```text
//! request {image, deadline, priority}
//!    │
//!    ├── cache.get(hash(image)) ──hit──> response (no inference)
//!    ▼
//! selector.choose(predictor, pool views, slo)
//!    ├── Route(acl pool)    — accurate path fits the budget
//!    ├── Route(quant pool)  — only the int8 path fits
//!    └── Shed               — structured `overloaded` rejection
//! ```
//!
//! Each model *generation* owns one [`PolicyCtx`] shared by its engine
//! queues (DESIGN.md §8): the shared runtime's workers feed the
//! predictor and fill the cache after each batch they execute for that
//! generation, the submit path reads both, and because the ctx is
//! per-generation a cache hit or latency estimate can never cross
//! models or weight generations.  Predictor keys stay (engine, batch)
//! *within* a generation's ctx — the shared runtime changes who
//! executes, not how policy state is namespaced.

pub mod cache;
pub mod deadline;
pub mod predictor;
pub mod selector;

use std::sync::atomic::{AtomicU64, Ordering};

pub use cache::{
    bytes_key, bytes_key_parts, image_key, CacheStats, CachedResult, ResponseCache,
};
pub use deadline::{Priority, Slo, Urgency};
pub use predictor::{default_prior_ms, LatencyPredictor, PredictorRow};
pub use selector::{Decision, PoolView, Selector};

/// Shared policy state: predictor + cache + shed accounting.
pub struct PolicyCtx {
    pub predictor: LatencyPredictor,
    pub cache: ResponseCache,
    /// Requests shed at admission (no variant predicted to meet the SLO).
    pub shed_predicted: AtomicU64,
    /// Admitted requests shed in-queue after their deadline passed.
    pub shed_expired: AtomicU64,
}

impl PolicyCtx {
    pub fn new(ewma_alpha: f64, cache_capacity: usize) -> PolicyCtx {
        PolicyCtx {
            predictor: LatencyPredictor::new(ewma_alpha),
            cache: ResponseCache::new(cache_capacity),
            shed_predicted: AtomicU64::new(0),
            shed_expired: AtomicU64::new(0),
        }
    }

    pub fn shed_predicted_count(&self) -> u64 {
        self.shed_predicted.load(Ordering::Relaxed)
    }

    pub fn shed_expired_count(&self) -> u64 {
        self.shed_expired.load(Ordering::Relaxed)
    }
}

/// One engine queue's state in a [`PolicySnapshot`].
#[derive(Debug, Clone)]
pub struct PoolSnapshot {
    pub engine: &'static str,
    /// This queue's current weighted fair share of the shared worker
    /// fleet (≥ 1; equals the whole fleet only when no other queue is
    /// contended) — the drain-parallelism bound the selector's
    /// completion prediction uses.  Workers are no longer owned per
    /// pool.
    pub workers: usize,
    pub queued: usize,
    pub capacity: usize,
    pub predicted_ms: f64,
    pub samples: u64,
}

/// One registered model's policy state in a [`PolicySnapshot`] —
/// predictor-backed pool views plus the per-generation cache and shed
/// counters.  Policy state is structurally namespaced by model: each
/// model generation owns its own [`PolicyCtx`], so rows never share a
/// predictor or cache (DESIGN.md §8).
#[derive(Debug, Clone)]
pub struct ModelPolicySnapshot {
    pub model: String,
    /// Generation currently serving (0 = none).
    pub generation: u64,
    /// False for lazily-registered models nobody has addressed yet.
    pub loaded: bool,
    pub pools: Vec<PoolSnapshot>,
    pub cache: CacheStats,
    pub shed_predicted: u64,
    pub shed_expired: u64,
}

/// Everything `{"cmd":"policy"}` reports.  The top-level `pools`/`cache`
/// fields mirror the default model (wire compatibility with the
/// pre-registry protocol); `models` carries the full per-model table.
#[derive(Debug, Clone)]
pub struct PolicySnapshot {
    pub adaptive: bool,
    pub pools: Vec<PoolSnapshot>,
    pub cache: CacheStats,
    pub shed_predicted: u64,
    pub shed_expired: u64,
    pub models: Vec<ModelPolicySnapshot>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_counters_start_zero() {
        let ctx = PolicyCtx::new(0.2, 8);
        assert_eq!(ctx.shed_predicted_count(), 0);
        assert_eq!(ctx.shed_expired_count(), 0);
        assert!(ctx.cache.enabled());
    }
}
