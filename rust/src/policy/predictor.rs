//! Online latency predictor: an EWMA of observed engine execution time
//! per (engine, batch size), fed by the workers after every batch.
//!
//! Following Marco et al. (adaptive model selection, 1911.04946), the
//! predictor starts from paper-derived priors (Fig 3/4 single-image
//! latencies) and converges onto the deployment's real numbers as
//! samples arrive — thermal throttling, contention, and big.LITTLE
//! placement all fold into the same moving average.  Predictions are
//! deliberately simple (no queueing theory): completion ≈ backlog drain
//! time + own batch execution, which is what the selector needs to
//! compare against a deadline.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::engine::EngineKind;

/// Paper-derived prior for one image, in ms (Fig 3: TF 420 → ACL 320;
/// Fig 4: int8 ≈ 4x off the fp32 baseline on conv-bound stages).
pub fn default_prior_ms(kind: EngineKind) -> f64 {
    match kind {
        EngineKind::AclStaged => 320.0,
        EngineKind::AclFused => 300.0,
        EngineKind::AclProbe => 340.0,
        EngineKind::TfBaseline => 420.0,
        EngineKind::Quant => 110.0,
        // Simulation engine: effectively free (engine::sim's fixed
        // per-image busy-wait).
        EngineKind::Sim => 1.0,
    }
}

#[derive(Debug, Clone, Copy)]
struct Ewma {
    value_ms: f64,
    samples: u64,
}

/// One predictor row, as exposed by `{"cmd":"policy"}`.
#[derive(Debug, Clone)]
pub struct PredictorRow {
    pub engine: EngineKind,
    pub batch: usize,
    pub ewma_ms: f64,
    pub samples: u64,
}

/// Thread-safe EWMA store.  Cheap: one short mutex hold per batch on the
/// worker side and per admission on the selector side.
pub struct LatencyPredictor {
    alpha: f64,
    cells: Mutex<BTreeMap<(EngineKind, usize), Ewma>>,
}

impl LatencyPredictor {
    /// `alpha` is the EWMA weight of the newest sample, in (0, 1].
    pub fn new(alpha: f64) -> LatencyPredictor {
        LatencyPredictor {
            alpha: alpha.clamp(1e-3, 1.0),
            cells: Mutex::new(BTreeMap::new()),
        }
    }

    /// Seed a prior so the selector has something to reason with before
    /// the first real sample (counted as zero samples).
    pub fn seed(&self, engine: EngineKind, batch: usize, ms: f64) {
        let mut g = self.cells.lock().unwrap();
        g.entry((engine, batch.max(1))).or_insert(Ewma {
            value_ms: ms.max(0.0),
            samples: 0,
        });
    }

    /// Record one observed batch execution time.
    pub fn record(&self, engine: EngineKind, batch: usize, exec_ms: f64) {
        if !exec_ms.is_finite() || exec_ms < 0.0 {
            return;
        }
        let mut g = self.cells.lock().unwrap();
        let cell = g.entry((engine, batch.max(1))).or_insert(Ewma {
            value_ms: exec_ms,
            samples: 0,
        });
        if cell.samples == 0 {
            // First real sample replaces the prior outright.
            cell.value_ms = exec_ms;
        } else {
            cell.value_ms = self.alpha * exec_ms + (1.0 - self.alpha) * cell.value_ms;
        }
        cell.samples += 1;
    }

    /// Predicted execution time for one batch of `batch` images.
    ///
    /// Lookup order: exact (engine, batch) bucket; else the nearest
    /// recorded bucket for the engine scaled linearly by batch ratio
    /// (sub-linear batching gains make this pessimistic — safe for
    /// deadline admission); else the paper prior times `batch`.
    pub fn batch_ms(&self, engine: EngineKind, batch: usize) -> f64 {
        let batch = batch.max(1);
        let g = self.cells.lock().unwrap();
        if let Some(c) = g.get(&(engine, batch)) {
            return c.value_ms;
        }
        let nearest = g
            .iter()
            .filter(|((k, _), _)| *k == engine)
            .min_by_key(|((_, b), _)| b.abs_diff(batch));
        match nearest {
            Some(((_, b), c)) => c.value_ms * batch as f64 / *b as f64,
            None => default_prior_ms(engine) * batch as f64,
        }
    }

    /// Predicted per-image cost, from the `batch`-sized bucket.
    pub fn per_image_ms(&self, engine: EngineKind, batch: usize) -> f64 {
        self.batch_ms(engine, batch) / batch.max(1) as f64
    }

    /// Predicted completion time for a newly admitted request:
    /// backlog drain (`queued_images` spread over `workers`) plus the
    /// request's own batch execution.
    pub fn completion_ms(
        &self,
        engine: EngineKind,
        queued_images: usize,
        workers: usize,
        batch_hint: usize,
    ) -> f64 {
        let per = self.per_image_ms(engine, batch_hint);
        let wait = per * queued_images as f64 / workers.max(1) as f64;
        wait + self.batch_ms(engine, batch_hint)
    }

    /// Total real samples recorded for an engine (any batch size).
    pub fn samples(&self, engine: EngineKind) -> u64 {
        let g = self.cells.lock().unwrap();
        g.iter()
            .filter(|((k, _), _)| *k == engine)
            .map(|(_, c)| c.samples)
            .sum()
    }

    /// All rows, for introspection.
    pub fn snapshot(&self) -> Vec<PredictorRow> {
        let g = self.cells.lock().unwrap();
        g.iter()
            .map(|(&(engine, batch), c)| PredictorRow {
                engine,
                batch,
                ewma_ms: c.value_ms,
                samples: c.samples,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_sample_replaces_prior() {
        let p = LatencyPredictor::new(0.2);
        p.seed(EngineKind::Quant, 1, 110.0);
        assert_eq!(p.batch_ms(EngineKind::Quant, 1), 110.0);
        p.record(EngineKind::Quant, 1, 80.0);
        assert_eq!(p.batch_ms(EngineKind::Quant, 1), 80.0);
    }

    #[test]
    fn ewma_converges_toward_new_level() {
        let p = LatencyPredictor::new(0.5);
        p.record(EngineKind::AclStaged, 1, 100.0);
        for _ in 0..20 {
            p.record(EngineKind::AclStaged, 1, 300.0);
        }
        let v = p.batch_ms(EngineKind::AclStaged, 1);
        assert!((v - 300.0).abs() < 1.0, "ewma {v}");
    }

    #[test]
    fn nearest_bucket_scales_linearly() {
        let p = LatencyPredictor::new(0.2);
        p.record(EngineKind::AclStaged, 2, 200.0);
        // batch 4 has no bucket: scale the batch-2 EWMA by 4/2.
        assert!((p.batch_ms(EngineKind::AclStaged, 4) - 400.0).abs() < 1e-9);
        assert!((p.per_image_ms(EngineKind::AclStaged, 4) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn falls_back_to_paper_prior() {
        let p = LatencyPredictor::new(0.2);
        let v = p.batch_ms(EngineKind::TfBaseline, 1);
        assert_eq!(v, default_prior_ms(EngineKind::TfBaseline));
    }

    #[test]
    fn completion_includes_backlog() {
        let p = LatencyPredictor::new(0.2);
        p.record(EngineKind::Quant, 1, 100.0);
        // 4 queued images over 2 workers = 200ms wait + 100ms own exec.
        let c = p.completion_ms(EngineKind::Quant, 4, 2, 1);
        assert!((c - 300.0).abs() < 1e-9, "completion {c}");
    }

    #[test]
    fn ignores_garbage_samples() {
        let p = LatencyPredictor::new(0.2);
        p.record(EngineKind::Quant, 1, f64::NAN);
        p.record(EngineKind::Quant, 1, -5.0);
        assert_eq!(p.samples(EngineKind::Quant), 0);
    }
}
