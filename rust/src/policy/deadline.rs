//! Per-request SLOs: deadlines, priorities, and the urgency order the
//! coordinator's queues use.
//!
//! The wire protocol carries `{"deadline_ms": 250, "priority": "hi"}`
//! alongside the image; both are optional.  A request with no deadline
//! never expires and sorts after every deadlined request of the same
//! priority (deadlined work is the scarce kind — serve it first).
//!
//! Invariants (property-tested in rust/tests/policy_props.rs):
//! * urgency order is total: hi < normal < lo, then earlier deadline
//!   first, then no-deadline last;
//! * a request only counts as expired once `now - submitted > deadline`;
//! * shedding an expired request always produces a structured rejection
//!   (enforced at the worker; see coordinator::worker).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Request priority class (three levels are plenty for an embedded
/// serving budget; ties break on deadline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Hi,
    Normal,
    Lo,
}

impl Priority {
    pub fn parse(s: &str) -> Result<Priority> {
        Ok(match s {
            "hi" | "high" => Priority::Hi,
            "normal" | "mid" | "default" => Priority::Normal,
            "lo" | "low" => Priority::Lo,
            _ => bail!("unknown priority '{s}' (hi|normal|lo)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Hi => "hi",
            Priority::Normal => "normal",
            Priority::Lo => "lo",
        }
    }

    /// Scheduling rank: lower serves first.
    pub fn rank(&self) -> u8 {
        match self {
            Priority::Hi => 0,
            Priority::Normal => 1,
            Priority::Lo => 2,
        }
    }
}

/// The service-level objective attached to one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Completion budget measured from submission.  `None` = best-effort.
    pub deadline: Option<Duration>,
    pub priority: Priority,
}

impl Default for Slo {
    fn default() -> Slo {
        Slo {
            deadline: None,
            priority: Priority::Normal,
        }
    }
}

impl Slo {
    pub fn with_deadline_ms(ms: f64) -> Slo {
        Slo {
            deadline: Some(Duration::from_secs_f64(ms / 1e3)),
            priority: Priority::Normal,
        }
    }

    pub fn deadline_ms(&self) -> Option<f64> {
        self.deadline.map(|d| d.as_secs_f64() * 1e3)
    }

    /// Budget remaining at `now` for a request submitted at `submitted`,
    /// in ms.  `None` when the request has no deadline.
    pub fn remaining_ms(&self, submitted: Instant, now: Instant) -> Option<f64> {
        self.deadline.map(|d| {
            let spent = now.saturating_duration_since(submitted);
            (d.as_secs_f64() - spent.as_secs_f64()) * 1e3
        })
    }

    /// Has the deadline already passed?  Best-effort requests never
    /// expire.
    pub fn expired(&self, submitted: Instant, now: Instant) -> bool {
        match self.deadline {
            Some(d) => now.saturating_duration_since(submitted) > d,
            None => false,
        }
    }
}

/// Absolute-deadline component of [`Urgency`].  Variant order is the
/// sort order: a concrete deadline beats "no deadline".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum DeadlineKey {
    At(Instant),
    None,
}

/// Total urgency order for queue sorting: priority rank first, then
/// absolute deadline (earliest first), no-deadline last.  Stable sorts
/// preserve FIFO among equals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Urgency {
    rank: u8,
    deadline: DeadlineKey,
}

impl Urgency {
    pub fn of(slo: &Slo, submitted: Instant) -> Urgency {
        Urgency {
            rank: slo.priority.rank(),
            deadline: match slo.deadline {
                Some(d) => DeadlineKey::At(submitted + d),
                None => DeadlineKey::None,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_parse_roundtrip() {
        for p in [Priority::Hi, Priority::Normal, Priority::Lo] {
            assert_eq!(Priority::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(Priority::parse("high").unwrap(), Priority::Hi);
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn expiry_respects_deadline() {
        let t0 = Instant::now();
        let slo = Slo::with_deadline_ms(50.0);
        assert!(!slo.expired(t0, t0));
        assert!(!slo.expired(t0, t0 + Duration::from_millis(50)));
        assert!(slo.expired(t0, t0 + Duration::from_millis(51)));
        assert!(!Slo::default().expired(t0, t0 + Duration::from_secs(3600)));
    }

    #[test]
    fn remaining_budget() {
        let t0 = Instant::now();
        let slo = Slo::with_deadline_ms(100.0);
        let r = slo.remaining_ms(t0, t0 + Duration::from_millis(40)).unwrap();
        assert!((r - 60.0).abs() < 1.0, "remaining {r}");
        assert_eq!(Slo::default().remaining_ms(t0, t0), None);
    }

    #[test]
    fn urgency_total_order() {
        let t0 = Instant::now();
        let hi_late = Urgency::of(
            &Slo {
                deadline: Some(Duration::from_millis(500)),
                priority: Priority::Hi,
            },
            t0,
        );
        let hi_soon = Urgency::of(
            &Slo {
                deadline: Some(Duration::from_millis(100)),
                priority: Priority::Hi,
            },
            t0,
        );
        let normal_soon = Urgency::of(
            &Slo {
                deadline: Some(Duration::from_millis(1)),
                priority: Priority::Normal,
            },
            t0,
        );
        let hi_best_effort = Urgency::of(
            &Slo {
                deadline: None,
                priority: Priority::Hi,
            },
            t0,
        );
        assert!(hi_soon < hi_late);
        assert!(hi_late < hi_best_effort);
        assert!(hi_best_effort < normal_soon);
    }
}
