//! Bounded LRU response cache keyed on image content hash.
//!
//! Embedded vision streams repeat frames (static scenes, duplicated
//! keyframes), so identical inputs are common; SqueezeNet inference is
//! deterministic, so a repeated frame's classification can be served
//! from memory bit-identically.  Keys are a 64-bit FNV-1a hash of the
//! preprocessed f32 pixels — content addressing, so the hit path is
//! independent of how the frame arrived (ppm path vs synthetic seed).
//!
//! Invariants (property-tested in rust/tests/policy_props.rs):
//! * a hit returns exactly the inserted value (bit-identical top-5);
//! * the cache never holds more than `capacity` entries;
//! * eviction is least-recently-used (gets refresh recency).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The cacheable part of an inference response.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedResult {
    pub top1: usize,
    pub top5: Vec<(usize, f32)>,
}

/// Cache statistics for `{"cmd":"policy"}`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub capacity: usize,
}

struct Lru {
    capacity: usize,
    tick: u64,
    /// key -> (value, recency tick at last touch)
    map: HashMap<u64, (CachedResult, u64)>,
    /// recency tick -> key (oldest tick = LRU victim)
    order: BTreeMap<u64, u64>,
}

impl Lru {
    fn touch(&mut self, key: u64) {
        let old_tick = match self.map.get(&key) {
            Some((_, t)) => *t,
            None => return,
        };
        self.order.remove(&old_tick);
        self.tick += 1;
        let t = self.tick;
        self.order.insert(t, key);
        if let Some(entry) = self.map.get_mut(&key) {
            entry.1 = t;
        }
    }
}

/// Thread-safe bounded LRU cache.  `capacity == 0` disables caching
/// (every lookup misses, inserts are dropped).
pub struct ResponseCache {
    inner: Mutex<Lru>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResponseCache {
    pub fn new(capacity: usize) -> ResponseCache {
        ResponseCache {
            inner: Mutex::new(Lru {
                capacity,
                tick: 0,
                map: HashMap::new(),
                order: BTreeMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.lock().unwrap().capacity > 0
    }

    /// Look up a frame hash; a hit refreshes recency.
    pub fn get(&self, key: u64) -> Option<CachedResult> {
        self.lookup(key, true)
    }

    /// Like [`get`] but a miss is not counted — used by layered key
    /// probes (wire key before decode, content key after) so one request
    /// never counts two misses.  Hits count and refresh recency as
    /// usual.
    pub fn peek(&self, key: u64) -> Option<CachedResult> {
        self.lookup(key, false)
    }

    fn lookup(&self, key: u64, count_miss: bool) -> Option<CachedResult> {
        let mut g = self.inner.lock().unwrap();
        if g.capacity == 0 {
            if count_miss {
                self.misses.fetch_add(1, Ordering::Relaxed);
            }
            return None;
        }
        match g.map.get(&key).map(|(v, _)| v.clone()) {
            Some(v) => {
                g.touch(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                if count_miss {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                }
                None
            }
        }
    }

    /// Insert (or refresh) a result, evicting the LRU entry when full.
    pub fn put(&self, key: u64, value: CachedResult) {
        let mut g = self.inner.lock().unwrap();
        if g.capacity == 0 {
            return;
        }
        if g.map.contains_key(&key) {
            g.touch(key);
            if let Some(entry) = g.map.get_mut(&key) {
                entry.0 = value;
            }
            return;
        }
        while g.map.len() >= g.capacity {
            // BTreeMap iteration is ascending: first entry is the LRU.
            let victim = match g.order.iter().next() {
                Some((&t, &k)) => (t, k),
                None => break,
            };
            g.order.remove(&victim.0);
            g.map.remove(&victim.1);
        }
        g.tick += 1;
        let t = g.tick;
        g.order.insert(t, key);
        g.map.insert(key, (value, t));
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            len: g.map.len(),
            capacity: g.capacity,
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over the f32 bit patterns — the frame's content address.
/// ~0.6 MB per 227x227x3 frame hashes in well under a millisecond, two
/// orders of magnitude below an inference.  Operates on borrowed data
/// (a pooled lease or view), never a clone.
pub fn image_key(pixels: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for v in pixels {
        h = fnv1a(h, &v.to_bits().to_le_bytes());
    }
    h
}

/// FNV-1a over raw bytes — the pre-decode wire key (hash of the request's
/// image spec).  Wire keys and content keys share one table; the inputs
/// live in disjoint domains (tagged spec bytes vs ~0.6 MB pixel streams),
/// so 64-bit collisions between them are as unlikely as any FNV pair.
pub fn bytes_key(bytes: &[u8]) -> u64 {
    fnv1a(FNV_OFFSET, bytes)
}

/// `bytes_key` over a discontiguous byte sequence: hashes the parts as
/// if concatenated, without copying them into one buffer.  The wire
/// plane uses this to key a request straight off its raw value span in
/// the pooled read buffer (domain tag + digit span), so the hot path
/// neither re-encodes the seed nor allocates.
pub fn bytes_key_parts(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for p in parts {
        h = fnv1a(h, p);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(top1: usize) -> CachedResult {
        CachedResult {
            top1,
            top5: vec![(top1, 0.5), (top1 + 1, 0.25)],
        }
    }

    #[test]
    fn hit_returns_inserted_value() {
        let c = ResponseCache::new(4);
        assert_eq!(c.get(7), None);
        c.put(7, result(694));
        assert_eq!(c.get(7), Some(result(694)));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
    }

    #[test]
    fn capacity_is_a_hard_bound_with_lru_eviction() {
        let c = ResponseCache::new(2);
        c.put(1, result(1));
        c.put(2, result(2));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(1).is_some());
        c.put(3, result(3));
        assert_eq!(c.len(), 2);
        assert!(c.get(1).is_some(), "recently used entry evicted");
        assert_eq!(c.get(2), None, "LRU entry survived");
        assert!(c.get(3).is_some());
    }

    #[test]
    fn reinsert_refreshes_value_and_recency() {
        let c = ResponseCache::new(2);
        c.put(1, result(1));
        c.put(2, result(2));
        c.put(1, result(10)); // refresh: 2 is now LRU
        c.put(3, result(3));
        assert_eq!(c.get(1), Some(result(10)));
        assert_eq!(c.get(2), None);
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ResponseCache::new(0);
        c.put(1, result(1));
        assert_eq!(c.get(1), None);
        assert_eq!(c.len(), 0);
        assert!(!c.enabled());
    }

    #[test]
    fn peek_counts_hits_but_not_misses() {
        let c = ResponseCache::new(2);
        assert_eq!(c.peek(1), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        c.put(1, result(5));
        assert_eq!(c.peek(1), Some(result(5)));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
        // peek refreshes recency like get.
        c.put(2, result(2));
        c.peek(1);
        c.put(3, result(3));
        assert!(c.get(1).is_some());
        assert_eq!(c.get(2), None, "LRU victim should have been 2");
    }

    #[test]
    fn bytes_key_is_stable_and_distinct() {
        assert_eq!(bytes_key(b"s:42"), bytes_key(b"s:42"));
        assert_ne!(bytes_key(b"s:42"), bytes_key(b"s:43"));
        assert_ne!(bytes_key(b""), bytes_key(b"\x00"));
    }

    #[test]
    fn bytes_key_parts_matches_concatenation() {
        assert_eq!(bytes_key_parts(&[b"s", b"42"]), bytes_key(b"s42"));
        assert_eq!(bytes_key_parts(&[b"s42"]), bytes_key(b"s42"));
        assert_eq!(bytes_key_parts(&[]), bytes_key(b""));
        assert_ne!(bytes_key_parts(&[b"s", b"42"]), bytes_key_parts(&[b"s4", b"3"]));
    }

    #[test]
    fn image_key_is_content_addressed() {
        let a = vec![0.0f32, 1.0, 2.0];
        let b = vec![0.0f32, 1.0, 2.0];
        let cdat = vec![0.0f32, 1.0, 2.0001];
        assert_eq!(image_key(&a), image_key(&b));
        assert_ne!(image_key(&a), image_key(&cdat));
        // -0.0 and 0.0 differ bitwise: distinct frames, distinct keys.
        assert_ne!(image_key(&[0.0]), image_key(&[-0.0]));
    }
}
