//! Metrics substrate: latency histograms, counters, the per-op timing
//! ledger (Fig 3 breakdown), and /proc system monitoring (Fig 3
//! utilization).

pub mod ledger;
pub mod sysmon;

use std::time::Duration;

use crate::util::{mean, ms, percentile_sorted};

/// Latency histogram with exact sample retention (bounded) + summary.
///
/// Serving runs are short (10^3..10^5 samples), so we keep raw samples up
/// to a cap and degrade to reservoir sampling beyond it — exact percentiles
/// for every experiment in this repo, bounded memory always.
#[derive(Debug, Clone)]
pub struct Histogram {
    samples_ms: Vec<f64>,
    cap: usize,
    /// Total observations (may exceed samples_ms.len() once capped).
    count: u64,
    sum_ms: f64,
    max_ms: f64,
    /// xorshift state for reservoir replacement.
    rng: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::with_cap(1 << 20)
    }
}

impl Histogram {
    pub fn with_cap(cap: usize) -> Histogram {
        Histogram {
            samples_ms: Vec::new(),
            cap: cap.max(16),
            count: 0,
            sum_ms: 0.0,
            max_ms: 0.0,
            rng: 0x9E3779B97F4A7C15,
        }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ms(ms(d));
    }

    pub fn record_ms(&mut self, v: f64) {
        self.count += 1;
        self.sum_ms += v;
        self.max_ms = self.max_ms.max(v);
        if self.samples_ms.len() < self.cap {
            self.samples_ms.push(v);
        } else {
            // Reservoir: replace a random slot with probability cap/count.
            self.rng ^= self.rng >> 12;
            self.rng ^= self.rng << 25;
            self.rng ^= self.rng >> 27;
            let idx = (self.rng.wrapping_mul(0x2545F4914F6CDD1D) % self.count) as usize;
            if idx < self.cap {
                self.samples_ms[idx] = v;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        let mut s = self.samples_ms.clone();
        // total_cmp: a NaN sample (e.g. a poisoned timer source) must
        // not panic the stats path mid-serve; NaNs sort above every
        // real sample and show up in the max, not as a crash.
        s.sort_by(f64::total_cmp);
        percentile_sorted(&s, p)
    }

    /// (mean, p50, p95, p99, max) in ms — the standard report row.
    pub fn summary(&self) -> (f64, f64, f64, f64, f64) {
        let mut s = self.samples_ms.clone();
        s.sort_by(f64::total_cmp);
        (
            self.mean_ms(),
            percentile_sorted(&s, 50.0),
            percentile_sorted(&s, 95.0),
            percentile_sorted(&s, 99.0),
            self.max_ms,
        )
    }

    pub fn merge(&mut self, other: &Histogram) {
        let pre_samples = other.samples_ms.len() as u64;
        let pre_sum: f64 = other.samples_ms.iter().sum();
        for &v in &other.samples_ms {
            self.record_ms(v);
        }
        // record_ms counted only retained samples; correct to true totals.
        self.count = self.count - pre_samples + other.count;
        self.sum_ms = self.sum_ms - pre_sum + other.sum_ms;
        self.max_ms = self.max_ms.max(other.max_ms);
    }
}

/// Throughput window: requests + images over a wall-clock span.
#[derive(Debug, Clone, Default)]
pub struct Throughput {
    pub requests: u64,
    pub images: u64,
    pub wall: Duration,
}

impl Throughput {
    pub fn rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn ips(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.images as f64 / self.wall.as_secs_f64()
        }
    }
}

/// Mean of a duration slice in ms (bench helper).
pub fn mean_ms(xs: &[Duration]) -> f64 {
    mean(&xs.iter().map(|d| ms(*d)).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_summary() {
        let mut h = Histogram::default();
        for v in [1.0, 2.0, 3.0, 4.0, 100.0] {
            h.record_ms(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_ms() - 22.0).abs() < 1e-9);
        assert_eq!(h.percentile_ms(50.0), 3.0);
        assert_eq!(h.max_ms(), 100.0);
    }

    #[test]
    fn histogram_reservoir_keeps_count_exact() {
        let mut h = Histogram::with_cap(16);
        for i in 0..1000 {
            h.record_ms(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.samples_ms.len(), 16);
        assert!((h.mean_ms() - 499.5).abs() < 1e-9);
        assert_eq!(h.max_ms(), 999.0);
    }

    #[test]
    fn nan_sample_never_panics_percentiles() {
        // A NaN latency sample in the ledger used to panic the
        // partial_cmp().unwrap() sort inside summary()/percentile_ms().
        let mut h = Histogram::default();
        for v in [1.0, f64::NAN, 3.0, 2.0] {
            h.record_ms(v);
        }
        let p50 = h.percentile_ms(50.0);
        assert!(p50.is_finite(), "finite percentile from mixed samples");
        let (_, p50s, p95, _, _) = h.summary();
        assert_eq!(p50, p50s);
        // NaN sorts above every real sample (total_cmp order), so high
        // percentiles may be NaN — but they must never panic.
        let _ = p95;
        let mut all_nan = Histogram::default();
        all_nan.record_ms(f64::NAN);
        let _ = all_nan.summary();
    }

    #[test]
    fn merge_combines_counts() {
        let mut a = Histogram::default();
        a.record_ms(1.0);
        let mut b = Histogram::default();
        b.record_ms(3.0);
        b.record_ms(5.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.mean_ms() - 3.0).abs() < 1e-9);
        assert_eq!(a.max_ms(), 5.0);
    }

    #[test]
    fn throughput_rates() {
        let t = Throughput {
            requests: 50,
            images: 100,
            wall: Duration::from_secs(2),
        };
        assert!((t.rps() - 25.0).abs() < 1e-9);
        assert!((t.ips() - 50.0).abs() < 1e-9);
    }
}
