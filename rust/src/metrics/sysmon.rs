//! /proc-based system monitor — reproduces the paper's Fig 3 utilization
//! numbers ("TF: 75% CPU, ~9 MB; ACL: 90% CPU, ~10 MB").
//!
//! A sampler thread reads `/proc/self/stat` (process jiffies) and
//! `/proc/stat` (total jiffies) plus `/proc/self/status` (VmRSS) on a
//! fixed interval; `stop()` returns average process CPU% (normalized to
//! one core, like `top`) and peak/average RSS deltas over the window.

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One utilization sample.
#[derive(Debug, Clone, Copy)]
struct Sample {
    proc_jiffies: u64,
    total_jiffies: u64,
    rss_kb: u64,
}

/// Utilization summary over a monitored window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    /// Process CPU as a fraction of one core (0.9 == 90%).
    pub cpu_frac: f64,
    pub avg_rss_mb: f64,
    pub peak_rss_mb: f64,
    pub samples: usize,
}

fn read_proc_self_stat() -> Result<u64> {
    let text = std::fs::read_to_string("/proc/self/stat")?;
    // Field 2 (comm) may contain spaces; skip past the closing paren.
    let rest = text
        .rsplit_once(')')
        .map(|(_, r)| r)
        .context("malformed /proc/self/stat")?;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    // After comm: state is field 0; utime is field 11, stime 12 (0-based).
    let utime: u64 = fields.get(11).context("utime")?.parse()?;
    let stime: u64 = fields.get(12).context("stime")?.parse()?;
    Ok(utime + stime)
}


fn read_proc_stat_total() -> Result<u64> {
    let text = std::fs::read_to_string("/proc/stat")?;
    let line = text.lines().next().context("empty /proc/stat")?;
    let mut total = 0u64;
    for f in line.split_whitespace().skip(1) {
        total += f.parse::<u64>().unwrap_or(0);
    }
    Ok(total)
}

fn read_rss_kb() -> Result<u64> {
    let text = std::fs::read_to_string("/proc/self/status")?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .context("VmRSS parse")?;
            return Ok(kb);
        }
    }
    anyhow::bail!("no VmRSS in /proc/self/status")
}

fn sample() -> Result<Sample> {
    Ok(Sample {
        proc_jiffies: read_proc_self_stat()?,
        total_jiffies: read_proc_stat_total()?,
        rss_kb: read_rss_kb()?,
    })
}

/// Background sampler; create with `Sysmon::start`, finish with `stop`.
pub struct Sysmon {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<Vec<Sample>>,
}

impl Sysmon {
    pub fn start(interval: Duration) -> Sysmon {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut out = Vec::new();
            if let Ok(s) = sample() {
                out.push(s);
            }
            while !flag.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                if let Ok(s) = sample() {
                    out.push(s);
                }
            }
            out
        });
        Sysmon { stop, handle }
    }

    /// Stop sampling and summarize the window.
    pub fn stop(self) -> Result<Utilization> {
        self.stop.store(true, Ordering::Relaxed);
        let samples = self
            .handle
            .join()
            .map_err(|_| anyhow::anyhow!("sysmon thread panicked"))?;
        if samples.len() < 2 {
            anyhow::bail!("sysmon window too short ({} samples)", samples.len());
        }
        let first = samples[0];
        let last = samples[samples.len() - 1];
        let dproc = last.proc_jiffies.saturating_sub(first.proc_jiffies) as f64;
        let dtotal = last.total_jiffies.saturating_sub(first.total_jiffies) as f64;
        let ncpu = num_cpus() as f64;
        // proc/total is "fraction of ALL cores"; scale to one-core units.
        let cpu_frac = if dtotal > 0.0 { dproc / dtotal * ncpu } else { 0.0 };
        let rss: Vec<f64> = samples.iter().map(|s| s.rss_kb as f64 / 1024.0).collect();
        Ok(Utilization {
            cpu_frac,
            avg_rss_mb: crate::util::mean(&rss),
            peak_rss_mb: rss.iter().cloned().fold(0.0, f64::max),
            samples: samples.len(),
        })
    }
}

pub fn num_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// One-shot process snapshot for the `"proc"` stats section: point-in-
/// time RSS, cumulative CPU seconds (user+system, all cores), process
/// uptime, and open file descriptors.  Unlike [`Sysmon`] this needs no
/// window — it is cheap enough to serve inline on a `{"cmd":"stats"}`
/// request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProcSnapshot {
    pub rss_mb: f64,
    /// Cumulative CPU time consumed by the process, in seconds.
    pub cpu_s: f64,
    /// Seconds since the process started.
    pub uptime_s: f64,
    /// Open file descriptors (connections + artifacts + pipes).
    pub open_fds: usize,
}

fn read_proc_uptime_s() -> Result<f64> {
    // /proc/self/stat field 21 (0-based, post-comm field 19) is
    // starttime in jiffies since boot; system uptime comes from
    // /proc/uptime.  Difference = process uptime.
    let text = std::fs::read_to_string("/proc/self/stat")?;
    let rest = text
        .rsplit_once(')')
        .map(|(_, r)| r)
        .context("malformed /proc/self/stat")?;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let start_jiffies: f64 = fields.get(19).context("starttime")?.parse()?;
    let boot_s: f64 = std::fs::read_to_string("/proc/uptime")?
        .split_whitespace()
        .next()
        .context("empty /proc/uptime")?
        .parse()?;
    Ok((boot_s - start_jiffies / jiffies_per_sec()).max(0.0))
}

/// Kernel clock-tick rate.  `sysconf(_SC_CLK_TCK)` is 100 on every
/// mainstream Linux config; hardcoding avoids a libc dependency.
fn jiffies_per_sec() -> f64 {
    100.0
}

fn count_open_fds() -> usize {
    std::fs::read_dir("/proc/self/fd")
        .map(|d| d.count().saturating_sub(1)) // the read_dir fd itself
        .unwrap_or(0)
}

/// Take a [`ProcSnapshot`] now.  Errors only if /proc is unreadable
/// (non-Linux), in which case callers should omit the section.
pub fn proc_snapshot() -> Result<ProcSnapshot> {
    Ok(ProcSnapshot {
        rss_mb: read_rss_kb()? as f64 / 1024.0,
        cpu_s: read_proc_self_stat()? as f64 / jiffies_per_sec(),
        uptime_s: read_proc_uptime_s()?,
        open_fds: count_open_fds(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proc_readers_work_on_linux() {
        assert!(read_proc_self_stat().is_ok());
        assert!(read_proc_stat_total().unwrap() > 0);
        assert!(read_rss_kb().unwrap() > 0);
    }

    #[test]
    fn proc_snapshot_is_sane() {
        let p = proc_snapshot().unwrap();
        assert!(p.rss_mb > 1.0, "rss {}", p.rss_mb);
        assert!(p.cpu_s >= 0.0);
        assert!(p.uptime_s >= 0.0);
        // stdin/stdout/stderr at minimum.
        assert!(p.open_fds >= 3, "fds {}", p.open_fds);
    }

    #[test]
    fn sysmon_measures_busy_loop() {
        let mon = Sysmon::start(Duration::from_millis(20));
        // Burn ~150ms of CPU.
        let t0 = std::time::Instant::now();
        let mut x = 0u64;
        while t0.elapsed() < Duration::from_millis(150) {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(x);
        let u = mon.stop().unwrap();
        assert!(u.samples >= 2);
        assert!(u.cpu_frac > 0.2, "cpu_frac {}", u.cpu_frac);
        assert!(u.avg_rss_mb > 1.0);
        assert!(u.peak_rss_mb >= u.avg_rss_mb);
    }
}
