//! Per-op timing ledger — the instrument behind the paper's Fig 3
//! breakdown ("group 1: convolution, ReLU, concatenate; group 2: pooling
//! and soft-max") and Fig 4's quant-overhead accounting.
//!
//! Engines record `(unit name, group, duration)` per executable launch;
//! the ledger aggregates per unit and per group.

use std::collections::BTreeMap;
use std::time::Duration;

/// Fig 3 / Fig 4 op groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Group {
    /// convolution + ReLU + concatenate
    Group1,
    /// pooling + soft-max (+ attenuation)
    Group2,
    /// quantize / dequantize overhead ops (Fig 4 only)
    Quant,
    /// dispatch & host work not attributable to an op
    Other,
}

impl Group {
    pub fn parse(s: &str) -> Group {
        match s {
            "group1" => Group::Group1,
            "group2" => Group::Group2,
            "quant" => Group::Quant,
            _ => Group::Other,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Group::Group1 => "group1(conv/relu/concat)",
            Group::Group2 => "group2(pool/softmax)",
            Group::Quant => "quant(q/dq overhead)",
            Group::Other => "other",
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct UnitStat {
    pub calls: u64,
    pub total: Duration,
}

/// Aggregated per-op / per-group timings for one measurement window.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    units: BTreeMap<String, (Group, UnitStat)>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    pub fn record(&mut self, unit: &str, group: Group, d: Duration) {
        let e = self
            .units
            .entry(unit.to_string())
            .or_insert((group, UnitStat::default()));
        e.1.calls += 1;
        e.1.total += d;
    }

    pub fn clear(&mut self) {
        self.units.clear();
    }

    /// Total time attributed to a group.
    pub fn group_total(&self, g: Group) -> Duration {
        self.units
            .values()
            .filter(|(gg, _)| *gg == g)
            .map(|(_, s)| s.total)
            .sum()
    }

    /// Total across all groups.
    pub fn total(&self) -> Duration {
        self.units.values().map(|(_, s)| s.total).sum()
    }

    /// Per-group totals in ms, ordered [group1, group2, quant, other].
    pub fn group_ms(&self) -> [f64; 4] {
        [
            crate::util::ms(self.group_total(Group::Group1)),
            crate::util::ms(self.group_total(Group::Group2)),
            crate::util::ms(self.group_total(Group::Quant)),
            crate::util::ms(self.group_total(Group::Other)),
        ]
    }

    /// Per-unit rows (name, group, calls, total ms), insertion-agnostic
    /// (sorted by name).
    pub fn rows(&self) -> Vec<(String, Group, u64, f64)> {
        self.units
            .iter()
            .map(|(k, (g, s))| (k.clone(), *g, s.calls, crate::util::ms(s.total)))
            .collect()
    }

    /// Merge another window into this one.
    pub fn merge(&mut self, other: &Ledger) {
        for (k, (g, s)) in &other.units {
            let e = self
                .units
                .entry(k.clone())
                .or_insert((*g, UnitStat::default()));
            e.1.calls += s.calls;
            e.1.total += s.total;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_classification_totals() {
        let mut l = Ledger::new();
        l.record("conv1", Group::Group1, Duration::from_millis(10));
        l.record("conv1", Group::Group1, Duration::from_millis(10));
        l.record("pool1", Group::Group2, Duration::from_millis(3));
        l.record("quantize", Group::Quant, Duration::from_millis(2));
        assert_eq!(l.group_total(Group::Group1), Duration::from_millis(20));
        assert_eq!(l.group_total(Group::Group2), Duration::from_millis(3));
        assert_eq!(l.group_total(Group::Quant), Duration::from_millis(2));
        assert_eq!(l.total(), Duration::from_millis(25));
        let rows = l.rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].2, 2); // conv1 called twice
    }

    #[test]
    fn parse_group_strings() {
        assert_eq!(Group::parse("group1"), Group::Group1);
        assert_eq!(Group::parse("group2"), Group::Group2);
        assert_eq!(Group::parse("quant"), Group::Quant);
        assert_eq!(Group::parse("???"), Group::Other);
    }

    #[test]
    fn merge_windows() {
        let mut a = Ledger::new();
        a.record("x", Group::Group1, Duration::from_millis(1));
        let mut b = Ledger::new();
        b.record("x", Group::Group1, Duration::from_millis(2));
        b.record("y", Group::Group2, Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.group_total(Group::Group1), Duration::from_millis(3));
        assert_eq!(a.group_total(Group::Group2), Duration::from_millis(4));
    }
}
