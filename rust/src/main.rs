//! `zuluko` — the embedded inference engine CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve      start the TCP serving frontend over the coordinator
//!   infer      one-shot inference on a PPM file or synthetic image
//!   bench      quick in-process latency benchmark of an engine
//!   inspect    print manifest / artifact inventory
//!
//! Examples:
//!   zuluko serve --engine acl --runtime-workers 4 --max-batch 8
//!   zuluko serve --model main=artifacts --model exp=artifacts-exp \
//!                --default-model main          # multi-model registry
//!   zuluko serve --models models.json          # registry from an index
//!   zuluko serve --models models.json --model-weight main=3 \
//!                --replica-cache-mb 64         # weighted shared runtime
//!   zuluko infer --ppm frame.ppm --engine acl-fused
//!   zuluko bench --engine tf --iters 10
//!   zuluko inspect
//!
//! Registry flags (DESIGN.md §8): `--model name=path` registers one
//! model (repeatable); `--models index.json` loads a whole index of the
//! shape `{"default":"name","preload":false,"models":{"name":"path"},
//! "weights":{"name":2.0}}`; `--default-model` picks which model serves
//! requests without a `model` field; `--preload-models` warms every
//! model at startup instead of on first request.  Clients address a
//! model with `{"id":1,"image":{...},"model":"name"}` and hot-reload
//! one with `{"cmd":"reload","model":"name"}`.
//!
//! Shared runtime flags (DESIGN.md §4): `--runtime-workers N` sizes the
//! fixed worker fleet (default: detected core count; `--workers` is the
//! legacy spelling), `--replica-cache-mb` bounds each worker's resident
//! engine replicas, `--model-weight name=w` skews the fair-share
//! scheduler (repeatable).
//!
//! Connection-plane flags (DESIGN.md §"Connection plane"):
//! `--conn-plane event|threads` picks the epoll reactor (default) or
//! the thread-per-connection ablation baseline; `--io-threads N` sizes
//! the reactor's IO set; `--max-connections` caps open sockets
//! (structured `at_capacity` reject beyond it); `--max-line-bytes`
//! bounds a request line; `--idle-timeout-ms` evicts idle connections
//! (0 disables).

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use zuluko::config::Config;
use zuluko::coordinator::Coordinator;
use zuluko::engine::build;
use zuluko::runtime::Manifest;
use zuluko::server::Server;
use zuluko::tensor::image::Image;
use zuluko::tensor::Tensor;
use zuluko::util::cli::Args;
use zuluko::{info, util};

/// Command-specific flags on top of [`Config::FLAGS`] (the config
/// flags live in one place so a new config knob can't be forgotten
/// here and fail `Args::parse` as unknown).
const EXTRA_FLAGS: &[&str] = &["ppm", "seed", "iters", "warmup", "top"];

fn known_flags() -> Vec<&'static str> {
    Config::FLAGS
        .iter()
        .chain(EXTRA_FLAGS.iter())
        .copied()
        .collect()
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let flags = known_flags();
    let args = Args::from_env(&flags).map_err(anyhow::Error::msg)?;
    let cfg = Config::from_args(&args)?;
    util::log::set_level(cfg.log_level);

    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&cfg),
        Some("infer") => cmd_infer(&cfg, &args),
        Some("bench") => cmd_bench(&cfg, &args),
        Some("inspect") => cmd_inspect(&cfg),
        Some(other) => bail!("unknown subcommand '{other}' (serve|infer|bench|inspect)"),
        None => {
            eprintln!("usage: zuluko <serve|infer|bench|inspect> [flags]");
            eprintln!("flags: {}", flags.join(", "));
            Ok(())
        }
    }
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    info!(
        "main",
        "starting coordinator (engine={} adaptive={} cache={} models={})",
        cfg.engine.as_str(),
        cfg.policy.adaptive,
        cfg.policy.cache_capacity,
        if cfg.registry.models.is_empty() {
            "single".to_string()
        } else {
            format!(
                "{:?} default='{}'",
                cfg.registry
                    .models
                    .iter()
                    .map(|(n, _)| n.as_str())
                    .collect::<Vec<_>>(),
                cfg.registry.effective_default()
            )
        }
    );
    let coord = Arc::new(Coordinator::start(cfg)?);
    let server = Server::start_with(coord.clone(), &cfg.listen, &cfg.server)?;
    info!(
        "main",
        "serving on {} — conn-plane={} io-threads={} max-connections={} — Ctrl-C to stop",
        server.addr(),
        cfg.server.conn_plane,
        cfg.server.io_threads,
        cfg.server.max_connections
    );
    // Serve until killed; periodic stats line.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let s = coord.stats();
        let c = server.conn_snapshot();
        info!(
            "main",
            "completed={} rejected={} queued={} p50={:.1}ms cache={}h/{}m \
             shed={}+{} pool={}h/{}m conns={} in-flight={}",
            s.completed,
            s.rejected,
            s.queued,
            s.latency_summary.1,
            s.cache_hits,
            s.cache_misses,
            s.shed_predicted,
            s.shed_expired,
            s.pool.hits,
            s.pool.misses,
            c.connections,
            c.in_flight
        );
    }
}

fn cmd_infer(cfg: &Config, args: &Args) -> Result<()> {
    let image = match args.get("ppm") {
        Some(path) => Image::load_ppm(std::path::Path::new(path))?,
        None => {
            let seed = args.get_usize("seed", 42).map_err(anyhow::Error::msg)? as u64;
            info!("main", "no --ppm given; using synthetic image seed={seed}");
            Image::synthetic(227, 227, seed)
        }
    };
    let input = image.to_input();

    let manifest = Manifest::load(&cfg.artifacts)?;
    let mut engine = build(cfg.engine, &manifest)?;
    let t0 = std::time::Instant::now();
    engine.warmup()?;
    info!("main", "engine {} ready in {:.1}s", engine.name(),
          t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let probs = engine.infer(&input)?;
    let dt = util::ms(t0.elapsed());

    let row = probs.unstack()?.remove(0);
    let k = args.get_usize("top", 5).map_err(anyhow::Error::msg)?;
    println!("inference: {dt:.1} ms on {}", engine.name());
    for (rank, (idx, p)) in row.topk(k).iter().enumerate() {
        println!("  #{:<2} class {:<4} p={:.4}", rank + 1, idx, p);
    }
    Ok(())
}

fn cmd_bench(cfg: &Config, args: &Args) -> Result<()> {
    let iters = args.get_usize("iters", 10).map_err(anyhow::Error::msg)?;
    let warmup = args.get_usize("warmup", 2).map_err(anyhow::Error::msg)?;
    let manifest = Manifest::load(&cfg.artifacts)?;
    let mut engine = build(cfg.engine, &manifest)?;
    engine.warmup()?;
    let input = Tensor::random(&[1, 227, 227, 3], 7);

    let stats = zuluko::bench::Bench::new(engine.name())
        .warmup(warmup)
        .iters(iters)
        .run(|| {
            engine.infer(&input).expect("infer");
        });
    println!("{}", zuluko::bench::Stats::HEADER);
    println!("{}", stats.row());

    let groups = engine.ledger().group_ms();
    let total: f64 = groups.iter().sum();
    if total > 0.0 {
        println!(
            "ledger: group1 {:.0}ms ({:.0}%), group2 {:.0}ms ({:.0}%), quant {:.0}ms",
            groups[0],
            groups[0] / total * 100.0,
            groups[1],
            groups[1] / total * 100.0,
            groups[2]
        );
    }
    Ok(())
}

fn cmd_inspect(cfg: &Config) -> Result<()> {
    let m = Manifest::load(&cfg.artifacts)
        .with_context(|| format!("artifacts at {}", cfg.artifacts.display()))?;
    println!("model: {} ({}x{}x{} -> {} classes)",
             m.model, m.input_hw, m.input_hw, m.input_channels, m.num_classes);
    println!("attenuation (dropout compensation): {}", m.attenuation);
    let total: usize = m.params.iter().map(|p| p.nelems).sum();
    println!("params: {} tensors, {} elems ({:.1} MB fp32, {:.1} MB int8)",
             m.params.len(), total, total as f64 * 4.0 / 1e6,
             m.params_q8.iter().map(|p| p.nelems).sum::<usize>() as f64 / 1e6);
    println!("batch sizes: {:?}", m.batch_sizes);
    println!("stages ({}):", m.stages.len());
    for s in &m.stages {
        println!("  {:>2} {:<8} {:?} -> {:?} [{} batch variants]",
                 s.index, s.name, s.in_shape, s.out_shape, s.artifacts.len());
    }
    println!("probe stages: {}", m.probe_stages.len());
    println!("baseline ops: {} fp32, {} quantized", m.ops.len(), m.quant_ops.len());
    println!("golden: top1={} (q8 {})", m.golden.top1, m.golden.top1_q8);
    println!("flops/image (conv only): {:.2} GFLOP",
             zuluko::model::conv_flops(&m) as f64 / 1e9);
    Ok(())
}
