//! Image I/O + preprocessing for the 227x227x3 input the paper serves.
//!
//! Supports binary PPM (P6) — the simplest real container — plus a
//! deterministic synthetic-image generator for workloads without files.
//! Preprocessing mirrors a typical embedded camera path: u8 RGB ->
//! center-crop/nearest-resize to 227 -> scale to [-1, 1].

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

use super::Tensor;
use crate::testkit::rng::Rng;

pub const INPUT_HW: usize = 227;

/// A decoded 8-bit RGB image (HWC).
#[derive(Debug, Clone)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub rgb: Vec<u8>,
}

impl Image {
    /// Deterministic synthetic image (workload generator input).
    pub fn synthetic(width: usize, height: usize, seed: u64) -> Image {
        let mut rng = Rng::new(seed ^ 0x1337_c0de);
        let rgb = (0..width * height * 3)
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        Image { width, height, rgb }
    }

    /// Parse a binary PPM (P6, maxval 255).
    pub fn from_ppm(bytes: &[u8]) -> Result<Image> {
        let mut pos = 0usize;
        let mut fields: Vec<usize> = Vec::new();
        // Header: "P6" <ws> width <ws> height <ws> maxval <single ws>
        if !bytes.starts_with(b"P6") {
            bail!("not a P6 ppm");
        }
        pos += 2;
        while fields.len() < 3 {
            // skip whitespace and comments
            while pos < bytes.len() {
                match bytes[pos] {
                    b' ' | b'\t' | b'\r' | b'\n' => pos += 1,
                    b'#' => {
                        while pos < bytes.len() && bytes[pos] != b'\n' {
                            pos += 1;
                        }
                    }
                    _ => break,
                }
            }
            let start = pos;
            while pos < bytes.len() && bytes[pos].is_ascii_digit() {
                pos += 1;
            }
            if start == pos {
                bail!("bad ppm header");
            }
            let v: usize = std::str::from_utf8(&bytes[start..pos])?
                .parse()
                .context("ppm header int")?;
            fields.push(v);
        }
        let (w, h, maxval) = (fields[0], fields[1], fields[2]);
        if maxval != 255 {
            bail!("only maxval 255 supported, got {maxval}");
        }
        pos += 1; // single whitespace after maxval
        let need = w * h * 3;
        if bytes.len() < pos + need {
            bail!("ppm truncated: need {} data bytes, have {}", need, bytes.len() - pos);
        }
        Ok(Image {
            width: w,
            height: h,
            rgb: bytes[pos..pos + need].to_vec(),
        })
    }

    pub fn load_ppm(path: &Path) -> Result<Image> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_ppm(&bytes)
    }

    /// Write as binary PPM.
    pub fn save_ppm(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        write!(f, "P6\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.rgb)?;
        Ok(())
    }

    /// Preprocess to the network input: center-crop to square, nearest-
    /// neighbour resize to 227x227, scale u8 -> [-1, 1] f32, NHWC (N=1).
    pub fn to_input(&self) -> Tensor {
        let mut data = vec![0.0f32; INPUT_HW * INPUT_HW * 3];
        self.to_input_into(&mut data);
        Tensor::new(&[1, INPUT_HW, INPUT_HW, 3], data).expect("input shape")
    }

    /// Preprocess into a caller-provided buffer — the zero-copy serving
    /// path hands a pooled lease here so steady-state decode allocates
    /// nothing.  `out` must hold exactly 227*227*3 elements; every slot
    /// is overwritten.
    pub fn to_input_into(&self, out: &mut [f32]) {
        self.to_input_into_sized(out, INPUT_HW);
    }

    /// Like [`Image::to_input_into`] but for an arbitrary square input
    /// size — registry models declare their own `input_hw` in the
    /// manifest, so the server decodes at whatever size the addressed
    /// model wants.  `out` must hold exactly `hw*hw*3` elements.
    pub fn to_input_into_sized(&self, out: &mut [f32], hw: usize) {
        Self::frame_to_input_into(&self.rgb, self.width, self.height, out, hw);
    }

    /// Preprocess raw u8 RGB (row-major HWC) pixels straight into the
    /// caller's buffer — the from-raw-frame path.  The binary frame
    /// lane calls this with the payload borrowed from the pooled
    /// connection read buffer, so wire-to-tensor decode never builds an
    /// owned `Image` copy.  `rgb` must hold exactly `width*height*3`
    /// bytes and `out` exactly `hw*hw*3` elements.
    pub fn frame_to_input_into(
        rgb: &[u8],
        width: usize,
        height: usize,
        out: &mut [f32],
        hw: usize,
    ) {
        assert!(hw > 0, "decode size must be positive");
        assert!(width > 0 && height > 0, "frame dims must be positive");
        assert_eq!(rgb.len(), width * height * 3, "frame payload size");
        assert_eq!(out.len(), hw * hw * 3, "decode buffer size");
        let side = width.min(height);
        let x0 = (width - side) / 2;
        let y0 = (height - side) / 2;
        let mut w = 0usize;
        for oy in 0..hw {
            let sy = y0 + oy * side / hw;
            for ox in 0..hw {
                let sx = x0 + ox * side / hw;
                let base = (sy * width + sx) * 3;
                for c in 0..3 {
                    let v = rgb[base + c] as f32;
                    out[w] = v / 127.5 - 1.0;
                    w += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_roundtrip() {
        let img = Image::synthetic(8, 6, 42);
        let dir = std::env::temp_dir().join("zuluko_img_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ppm");
        img.save_ppm(&path).unwrap();
        let back = Image::load_ppm(&path).unwrap();
        assert_eq!(back.width, 8);
        assert_eq!(back.height, 6);
        assert_eq!(back.rgb, img.rgb);
    }

    #[test]
    fn ppm_with_comments() {
        let mut bytes = b"P6\n# a comment\n2 1\n# another\n255\n".to_vec();
        bytes.extend_from_slice(&[1, 2, 3, 4, 5, 6]);
        let img = Image::from_ppm(&bytes).unwrap();
        assert_eq!((img.width, img.height), (2, 1));
        assert_eq!(img.rgb, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn ppm_rejects_truncated_and_bad_magic() {
        assert!(Image::from_ppm(b"P5\n1 1\n255\nX").is_err());
        let bytes = b"P6\n4 4\n255\n\x00".to_vec();
        assert!(Image::from_ppm(&bytes).is_err());
    }

    #[test]
    fn preprocess_shape_and_range() {
        let img = Image::synthetic(300, 250, 7);
        let t = img.to_input();
        assert_eq!(t.shape(), &[1, INPUT_HW, INPUT_HW, 3]);
        for &v in t.data() {
            assert!((-1.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn frame_decode_matches_owned_image_decode() {
        // The borrowed-payload path must be bit-identical to decoding
        // through an owned Image — the frame lane's correctness hinges
        // on it (byte-identical replies vs the JSON lane).
        let img = Image::synthetic(40, 30, 11);
        let mut via_image = vec![0.0f32; 16 * 16 * 3];
        img.to_input_into_sized(&mut via_image, 16);
        let mut via_frame = vec![9.0f32; 16 * 16 * 3];
        Image::frame_to_input_into(&img.rgb, 40, 30, &mut via_frame, 16);
        assert_eq!(via_image, via_frame);
    }

    #[test]
    fn preprocess_exact_size_is_identity_sampling() {
        let img = Image::synthetic(INPUT_HW, INPUT_HW, 9);
        let t = img.to_input();
        // pixel (0,0) channel 0 must map through the scale formula exactly
        let expect = img.rgb[0] as f32 / 127.5 - 1.0;
        assert_eq!(t.data()[0], expect);
    }
}
