//! Tensor arena: size-classed, bounded buffer pool with RAII leases.
//!
//! Every request used to heap-allocate its pixels several times between
//! socket and reply (decode `Vec`, `Tensor::stack`'s batch `Vec`, one
//! `Vec` per `unstack` row).  The pool turns the steady state into
//! *reuse*: decode writes into a leased buffer, workers assemble batches
//! into a leased batch buffer, and every lease returns to its size class
//! on drop — including panic and error unwinds, because return is `Drop`.
//!
//! [`TensorPool`] is a cheap handle (an `Arc` inside); clone it freely
//! across the coordinator, connection handlers, and workers.
//!
//! Invariants (tested in rust/tests/pool_props.rs):
//! * a dropped lease always returns its buffer to the pool (unless the
//!   size class is at its retention bound, in which case the buffer is
//!   freed and counted as `dropped`).  A class's bound is
//!   `per_class_cap` unless a startup [`TensorPool::prealloc`]
//!   reservation explicitly raised it (the decode class is reserved at
//!   queue depth);
//! * leased buffers always have exactly the requested length;
//! * the pool is safe under concurrent lease/return from worker threads;
//! * with pooling disabled (`--pool false`, the ablation mode) every
//!   lease is a fresh allocation and drops free normally — the serving
//!   path is identical either way.
//!
//! Buffer contents are **unspecified** on lease (stale data from the
//! previous user): every caller fully overwrites before reading, which
//! is what lets reuse skip a zeroing pass.

use std::collections::HashMap;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::view::TensorView;
use super::Tensor;

/// Pool counters for stats/introspection.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Leases served from a pooled buffer (no allocation).
    pub hits: u64,
    /// Leases that had to allocate (cold class, exhausted class, or
    /// pooling disabled).
    pub misses: u64,
    /// Buffers accepted back on lease drop.
    pub returned: u64,
    /// Buffers freed on drop because their class was at the bound.
    pub dropped: u64,
    /// Buffers currently shelved across all classes.
    pub buffers: usize,
}

/// One size class: its shelved buffers and its retention bound.  The
/// bound starts at the pool-wide `per_class_cap` and can be raised by
/// an explicit [`TensorPool::prealloc`] reservation (e.g. the decode
/// class is reserved at queue depth so a full admission queue of
/// in-flight leases still returns into the arena instead of churning
/// the allocator).
struct Shelf {
    cap: usize,
    bufs: Vec<Vec<f32>>,
}

/// Size class table: element count -> shelf.
struct Shelves {
    classes: HashMap<usize, Shelf>,
}

/// Hard bound on the number of size classes the pool will retain.  The
/// serving path uses a handful (one input size + one per compiled batch
/// size); `adopt` can see arbitrary caller sizes, and without this cap
/// a stream of odd-sized buffers would grow the class table — and the
/// retained memory — without bound.  Returns into unseen classes beyond
/// the cap are freed and counted as `dropped`.
const MAX_CLASSES: usize = 64;

struct PoolInner {
    shelves: Mutex<Shelves>,
    per_class_cap: usize,
    enabled: bool,
    hits: AtomicU64,
    misses: AtomicU64,
    returned: AtomicU64,
    dropped: AtomicU64,
}

/// Thread-safe buffer pool handle (clone = share).
#[derive(Clone)]
pub struct TensorPool {
    inner: Arc<PoolInner>,
}

impl fmt::Debug for TensorPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TensorPool")
            .field("enabled", &self.inner.enabled)
            .field("per_class_cap", &self.inner.per_class_cap)
            .field("shelved", &self.shelved())
            .finish()
    }
}

impl TensorPool {
    /// Enabled pool retaining up to `per_class_cap` buffers per size
    /// class.
    pub fn new(per_class_cap: usize) -> TensorPool {
        Self::with_mode(true, per_class_cap)
    }

    /// `enabled = false` is the ablation mode: every lease allocates and
    /// every drop frees, with identical call-site code.
    pub fn with_mode(enabled: bool, per_class_cap: usize) -> TensorPool {
        TensorPool {
            inner: Arc::new(PoolInner {
                shelves: Mutex::new(Shelves {
                    classes: HashMap::new(),
                }),
                per_class_cap: per_class_cap.max(1),
                enabled,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                returned: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// A pool that never retains anything (convenience for tests/tools).
    pub fn disabled() -> TensorPool {
        Self::with_mode(false, 1)
    }

    pub fn enabled(&self) -> bool {
        self.inner.enabled
    }

    /// Lease a buffer of exactly `n` elements.  Contents are unspecified
    /// — the caller must fully overwrite before reading.
    pub fn lease(&self, n: usize) -> Lease {
        if self.inner.enabled {
            let reused = {
                let mut g = self.inner.shelves.lock().unwrap();
                g.classes.get_mut(&n).and_then(|shelf| shelf.bufs.pop())
            };
            if let Some(buf) = reused {
                debug_assert_eq!(buf.len(), n);
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                return Lease {
                    buf,
                    pool: Some(self.clone()),
                };
            }
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        Lease {
            buf: vec![0.0; n],
            pool: if self.inner.enabled {
                Some(self.clone())
            } else {
                None
            },
        }
    }

    /// Wrap an existing buffer as a lease so it joins the pool on drop
    /// (recycles tensors handed in by library callers).
    pub fn adopt(&self, buf: Vec<f32>) -> Lease {
        Lease {
            buf,
            pool: if self.inner.enabled {
                Some(self.clone())
            } else {
                None
            },
        }
    }

    /// Reserve `count` buffers of `n` elements ahead of time (startup,
    /// so the steady state never allocates).  An explicit reservation
    /// raises this class's retention bound to `count` when that exceeds
    /// the pool-wide `per_class_cap` — e.g. the decode class is
    /// reserved at queue depth, otherwise a full admission queue of
    /// in-flight leases would overflow the default bound and churn the
    /// allocator on exactly the load pooling targets.
    pub fn prealloc(&self, n: usize, count: usize) {
        if !self.inner.enabled || n == 0 {
            return;
        }
        let mut g = self.inner.shelves.lock().unwrap();
        let default_cap = self.inner.per_class_cap;
        let shelf = g.classes.entry(n).or_insert_with(|| Shelf {
            cap: default_cap,
            bufs: Vec::new(),
        });
        shelf.cap = shelf.cap.max(count);
        while shelf.bufs.len() < count {
            shelf.bufs.push(vec![0.0; n]);
        }
    }

    /// Return a buffer to its size class (drop path; never panics even
    /// if the shelf mutex was poisoned by an unrelated panic).
    fn give(&self, buf: Vec<f32>) {
        if !self.inner.enabled || buf.is_empty() {
            return;
        }
        let n = buf.len();
        let Ok(mut g) = self.inner.shelves.lock() else {
            return;
        };
        if !g.classes.contains_key(&n) && g.classes.len() >= MAX_CLASSES {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let default_cap = self.inner.per_class_cap;
        let shelf = g.classes.entry(n).or_insert_with(|| Shelf {
            cap: default_cap,
            bufs: Vec::new(),
        });
        if shelf.bufs.len() < shelf.cap {
            shelf.bufs.push(buf);
            self.inner.returned.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Buffers currently shelved across all classes.
    pub fn shelved(&self) -> usize {
        self.inner
            .shelves
            .lock()
            .map(|g| g.classes.values().map(|s| s.bufs.len()).sum())
            .unwrap_or(0)
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            returned: self.inner.returned.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
            buffers: self.shelved(),
        }
    }
}

/// RAII handle on a pooled buffer: derefs to `[f32]`, returns the buffer
/// to its pool on drop (or frees it when pooling is disabled).
pub struct Lease {
    buf: Vec<f32>,
    pool: Option<TensorPool>,
}

impl fmt::Debug for Lease {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Lease")
            .field("len", &self.buf.len())
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl Lease {
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Detach from the pool: the buffer becomes a plain `Vec` and will
    /// not be returned.
    pub fn into_vec(mut self) -> Vec<f32> {
        self.pool = None;
        std::mem::take(&mut self.buf)
    }
}

impl Deref for Lease {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl DerefMut for Lease {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.give(std::mem::take(&mut self.buf));
        }
    }
}

/// A shape on top of a leased buffer — the pooled request/batch carrier.
/// API mirrors `Tensor` for the methods the serving path uses.
#[derive(Debug)]
pub struct PooledTensor {
    shape: Vec<usize>,
    buf: Lease,
}

impl PooledTensor {
    pub fn new(shape: &[usize], buf: Lease) -> Result<PooledTensor> {
        let n: usize = shape.iter().product();
        if n != buf.len() {
            bail!(
                "shape {:?} wants {} elems, lease has {}",
                shape,
                n,
                buf.len()
            );
        }
        Ok(PooledTensor {
            shape: shape.to_vec(),
            buf,
        })
    }

    /// Move an owned tensor into the pool's custody: no copy now, and
    /// its buffer is recycled once the request completes.
    pub fn from_tensor(t: Tensor, pool: &TensorPool) -> PooledTensor {
        let shape = t.shape().to_vec();
        let buf = pool.adopt(t.into_data());
        PooledTensor { shape, buf }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.buf
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn view(&self) -> TensorView<'_> {
        TensorView::new(&self.shape, &self.buf)
    }

    /// Copy out to an owned tensor (compat shim for non-hot-path code).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(&self.shape, self.buf.to_vec()).expect("pooled shape")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_returns_on_drop_and_is_reused() {
        let pool = TensorPool::new(4);
        {
            let mut l = pool.lease(8);
            l[0] = 7.0;
            assert_eq!(l.len(), 8);
        }
        let s = pool.stats();
        assert_eq!((s.misses, s.returned, s.buffers), (1, 1, 1));
        // Same class leases the shelved buffer back (stale contents).
        let l = pool.lease(8);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(l.len(), 8);
    }

    #[test]
    fn class_bound_is_hard() {
        let pool = TensorPool::new(2);
        let leases: Vec<Lease> = (0..5).map(|_| pool.lease(4)).collect();
        drop(leases);
        let s = pool.stats();
        assert_eq!(s.buffers, 2);
        assert_eq!(s.returned, 2);
        assert_eq!(s.dropped, 3);
    }

    #[test]
    fn class_count_is_bounded() {
        let pool = TensorPool::new(2);
        for n in 1..=(MAX_CLASSES + 8) {
            drop(pool.lease(n));
        }
        let s = pool.stats();
        assert_eq!(s.buffers, MAX_CLASSES);
        assert_eq!(s.dropped as usize, 8);
        // Established classes still accept returns.
        drop(pool.lease(1));
        assert_eq!(pool.stats().returned as usize, MAX_CLASSES + 1);
    }

    #[test]
    fn disabled_pool_never_retains() {
        let pool = TensorPool::disabled();
        drop(pool.lease(16));
        let s = pool.stats();
        assert_eq!((s.buffers, s.returned, s.misses), (0, 0, 1));
        assert!(!pool.enabled());
    }

    #[test]
    fn prealloc_reserves_and_raises_class_bound() {
        let pool = TensorPool::new(3);
        pool.prealloc(10, 8);
        assert_eq!(pool.shelved(), 8, "reservation may exceed default cap");
        // Prealloc'd buffers serve as hits, and the raised bound holds
        // a full reservation's worth of returns.
        let leases: Vec<Lease> = (0..8).map(|_| pool.lease(10)).collect();
        assert_eq!(pool.stats().hits, 8);
        drop(leases);
        let s = pool.stats();
        assert_eq!((s.returned, s.dropped, s.buffers), (8, 0, 8));
        // Un-reserved classes still bound at the pool default.
        let extra: Vec<Lease> = (0..5).map(|_| pool.lease(20)).collect();
        drop(extra);
        assert_eq!(pool.stats().dropped, 2);
    }

    #[test]
    fn into_vec_detaches() {
        let pool = TensorPool::new(4);
        let v = pool.lease(6).into_vec();
        assert_eq!(v.len(), 6);
        assert_eq!(pool.stats().returned, 0);
        assert_eq!(pool.shelved(), 0);
    }

    #[test]
    fn pooled_tensor_checks_shape_and_views() {
        let pool = TensorPool::new(4);
        assert!(PooledTensor::new(&[2, 4], pool.lease(7)).is_err());
        let mut pt = PooledTensor::new(&[2, 3], pool.lease(6)).unwrap();
        for (i, v) in pt.data_mut().iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(pt.view().row(1).data(), &[3.0, 4.0, 5.0]);
        assert_eq!(pt.to_tensor().shape(), &[2, 3]);
    }

    #[test]
    fn from_tensor_recycles_caller_buffers() {
        let pool = TensorPool::new(4);
        let t = Tensor::random(&[3, 2], 1);
        let want = t.data().to_vec();
        let pt = PooledTensor::from_tensor(t, &pool);
        assert_eq!(pt.data(), &want[..]);
        drop(pt);
        assert_eq!(pool.stats().returned, 1);
        assert_eq!(pool.stats().buffers, 1);
    }
}
