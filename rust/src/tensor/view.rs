//! Borrowed tensor views — the zero-copy half of the hot path.
//!
//! A [`TensorView`] is a shape + `&[f32]` pair: batch rows, reply
//! extraction, `argmax`/`topk`, and cache-key hashing all operate on
//! borrowed data instead of cloning a `Vec` per request (the old
//! `unstack` path allocated one `Vec<f32>` per batch member just to
//! read 5 numbers out of it).
//!
//! The reductions live here as free functions over `&[f32]` so `Tensor`,
//! `PooledTensor`, and `TensorView` share one implementation — and one
//! explicitly defined NaN order:
//!
//! * NaN sorts **below every number**: a NaN score never wins `argmax`
//!   and only appears in `topk` when fewer than `k` non-NaN entries
//!   exist;
//! * equal values tie-break toward the **lower index** (first occurrence
//!   wins, matching the historical behaviour of both functions).

use std::cmp::Ordering;

use super::Tensor;

/// Borrowed row-major f32 tensor (shape + data slices).
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    shape: &'a [usize],
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    /// `shape` must describe exactly `data.len()` elements.
    pub fn new(shape: &'a [usize], data: &'a [f32]) -> TensorView<'a> {
        debug_assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "view shape {shape:?} vs {} elems",
            data.len()
        );
        TensorView { shape, data }
    }

    pub fn shape(&self) -> &'a [usize] {
        self.shape
    }

    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Leading-dimension size (0 for a scalar view).
    pub fn num_rows(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    /// Row `i` of a `(N, *S)` view as a borrowed `S`-shaped view — the
    /// zero-copy replacement for `Tensor::unstack`.
    pub fn row(&self, i: usize) -> TensorView<'a> {
        assert!(!self.shape.is_empty(), "row() on scalar view");
        let rest = &self.shape[1..];
        let per: usize = rest.iter().product();
        TensorView {
            shape: rest,
            data: &self.data[i * per..(i + 1) * per],
        }
    }

    /// Copy out to an owned tensor (compat shim; the hot path never
    /// calls this).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(self.shape, self.data.to_vec()).expect("view shape")
    }

    /// Index of the maximum element (see module docs for NaN order).
    pub fn argmax(&self) -> usize {
        argmax(self.data)
    }

    /// Top-k `(index, value)` pairs, descending.
    pub fn topk(&self, k: usize) -> Vec<(usize, f32)> {
        topk(self.data, k)
    }
}

/// Total descending order on `(index, value)`: higher value first, NaN
/// below every number, equal values broken by lower index.  Returns
/// whether `a` outranks `b`.
fn outranks(a: (usize, f32), b: (usize, f32)) -> bool {
    match cmp_val(a.1, b.1) {
        Ordering::Greater => true,
        Ordering::Less => false,
        Ordering::Equal => a.0 < b.0,
    }
}

/// Value comparison with NaN pinned below -inf (NaN == NaN).
fn cmp_val(x: f32, y: f32) -> Ordering {
    match (x.is_nan(), y.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => x.partial_cmp(&y).expect("non-NaN compare"),
    }
}

/// Index of the maximum element; 0 for an empty or all-NaN slice
/// (matching the old `Tensor::argmax`).
pub fn argmax(data: &[f32]) -> usize {
    let mut best = 0usize;
    for i in 1..data.len() {
        if outranks((i, data[i]), (best, data[best])) {
            best = i;
        }
    }
    best
}

/// Top-k `(index, value)` pairs in descending order — O(n log k) via a
/// bounded min-heap (replaces the old O(n·k) sorted-insert).  The heap
/// root is always the *worst* kept entry, so each new element costs one
/// comparison against it and only heap work when it displaces something.
pub fn topk(data: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut out = Vec::with_capacity(k.min(data.len()));
    topk_into(data, k, &mut out);
    out
}

/// [`topk`] writing into a caller-provided buffer (cleared first) — the
/// zero-allocation variant for hot loops that reuse a scratch vec.
pub fn topk_into(data: &[f32], k: usize, out: &mut Vec<(usize, f32)>) {
    out.clear();
    if k == 0 {
        return;
    }
    for (i, &v) in data.iter().enumerate() {
        let e = (i, v);
        if out.len() < k {
            out.push(e);
            sift_up(out, out.len() - 1);
        } else if outranks(e, out[0]) {
            out[0] = e;
            sift_down(out, 0);
        }
    }
    out.sort_by(|&a, &b| {
        if outranks(a, b) {
            Ordering::Less
        } else if outranks(b, a) {
            Ordering::Greater
        } else {
            Ordering::Equal
        }
    });
}

/// Restore the min-heap (root = worst under `outranks`) after a push.
fn sift_up(h: &mut [(usize, f32)], mut i: usize) {
    while i > 0 {
        let p = (i - 1) / 2;
        if outranks(h[p], h[i]) {
            h.swap(i, p);
            i = p;
        } else {
            break;
        }
    }
}

/// Restore the min-heap after replacing the root.
fn sift_down(h: &mut [(usize, f32)], mut i: usize) {
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut worst = i;
        if l < h.len() && outranks(h[worst], h[l]) {
            worst = l;
        }
        if r < h.len() && outranks(h[worst], h[r]) {
            worst = r;
        }
        if worst == i {
            break;
        }
        h.swap(i, worst);
        i = worst;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_rows_match_unstack() {
        let t = Tensor::random(&[3, 4, 5], 11);
        let rows = t.unstack().unwrap();
        let v = t.view();
        assert_eq!(v.num_rows(), 3);
        for (i, owned) in rows.iter().enumerate() {
            let row = v.row(i);
            assert_eq!(row.shape(), owned.shape());
            assert_eq!(row.data(), owned.data());
        }
    }

    #[test]
    fn view_reductions_match_tensor() {
        let t = Tensor::random(&[64], 3);
        assert_eq!(t.view().argmax(), t.argmax());
        assert_eq!(t.view().topk(7), t.topk(7));
    }

    #[test]
    fn topk_matches_reference_sort() {
        let t = Tensor::random(&[200], 5);
        for k in [0, 1, 5, 199, 200, 300] {
            let got = topk(t.data(), k);
            let mut want: Vec<(usize, f32)> =
                t.data().iter().copied().enumerate().collect();
            want.sort_by(|&a, &b| {
                cmp_val(b.1, a.1).then(a.0.cmp(&b.0))
            });
            want.truncate(k);
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn nan_never_wins() {
        let data = [f32::NAN, 0.5, f32::NAN, 0.9, 0.1];
        assert_eq!(argmax(&data), 3);
        let tk = topk(&data, 3);
        assert_eq!(tk[0], (3, 0.9));
        assert_eq!(tk[1], (1, 0.5));
        assert_eq!(tk[2], (4, 0.1));
        // NaNs only surface when there aren't k real numbers.
        let tk5 = topk(&data, 5);
        assert_eq!(tk5.len(), 5);
        assert!(tk5[3].1.is_nan() && tk5[4].1.is_nan());
        assert_eq!((tk5[3].0, tk5[4].0), (0, 2), "NaN ties break by index");
    }

    #[test]
    fn all_nan_argmax_is_zero() {
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn ties_prefer_lower_index() {
        let data = [0.3, 0.9, 0.9, 0.3];
        assert_eq!(argmax(&data), 1);
        assert_eq!(topk(&data, 4), vec![(1, 0.9), (2, 0.9), (0, 0.3), (3, 0.3)]);
    }

    #[test]
    fn topk_into_reuses_scratch() {
        let mut scratch = Vec::with_capacity(4);
        topk_into(&[3.0, 1.0, 2.0], 2, &mut scratch);
        assert_eq!(scratch, vec![(0, 3.0), (2, 2.0)]);
        let cap = scratch.capacity();
        topk_into(&[5.0, 9.0], 2, &mut scratch);
        assert_eq!(scratch, vec![(1, 9.0), (0, 5.0)]);
        assert_eq!(scratch.capacity(), cap, "scratch must not reallocate");
    }

    #[test]
    fn topk_zero_and_oversized_k() {
        assert!(topk(&[1.0, 2.0], 0).is_empty());
        assert_eq!(topk(&[1.0, 2.0], 9), vec![(1, 2.0), (0, 1.0)]);
    }
}
