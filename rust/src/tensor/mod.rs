//! NHWC host-side tensor substrate.
//!
//! The coordinator needs a small amount of host tensor plumbing —
//! pre/post-processing, golden comparisons, batch packing — none of which
//! justifies an ndarray dependency.  `Tensor` is a flat `Vec<f32>` plus a
//! shape; the only operations implemented are the ones the request path
//! actually uses, each written to be allocation-conscious.

pub mod image;
pub mod pool;
pub mod view;

pub use pool::{Lease, PoolStats, PooledTensor, TensorPool};
pub use view::TensorView;

use anyhow::{bail, Result};

/// Row-major f32 tensor with runtime shape (rank <= 4 in practice).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elems, data has {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// Deterministic pseudo-random tensor (xorshift64*; see testkit::rng).
    pub fn random(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = crate::testkit::rng::Rng::new(seed);
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Stack `items` (each shape S) into one (N, *S) batch tensor.
    /// Single copy per item into a preallocated buffer.
    pub fn stack(items: &[&Tensor]) -> Result<Tensor> {
        let first = match items.first() {
            Some(t) => t,
            None => bail!("stack of zero tensors"),
        };
        let per = first.len();
        let mut data = Vec::with_capacity(per * items.len());
        for t in items {
            if t.shape != first.shape {
                bail!("stack shape mismatch: {:?} vs {:?}", t.shape, first.shape);
            }
            data.extend_from_slice(&t.data);
        }
        let mut shape = vec![items.len()];
        shape.extend_from_slice(&first.shape);
        Tensor::new(&shape, data)
    }

    /// Split a (N, *S) batch back into N tensors of shape S.
    pub fn unstack(&self) -> Result<Vec<Tensor>> {
        if self.shape.is_empty() {
            bail!("unstack of scalar");
        }
        let n = self.shape[0];
        let rest: Vec<usize> = self.shape[1..].to_vec();
        let per: usize = rest.iter().product();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(Tensor {
                shape: rest.clone(),
                data: self.data[i * per..(i + 1) * per].to_vec(),
            });
        }
        Ok(out)
    }

    /// Borrow as a [`TensorView`] — the zero-copy handle the serving
    /// path reads rows/reductions through.
    pub fn view(&self) -> view::TensorView<'_> {
        view::TensorView::new(&self.shape, &self.data)
    }

    /// Index of the maximum element (NaN order defined in [`view`]).
    pub fn argmax(&self) -> usize {
        view::argmax(&self.data)
    }

    /// Top-k (index, value) pairs, descending — bounded min-heap,
    /// O(n log k) (NaN order defined in [`view`]).
    pub fn topk(&self, k: usize) -> Vec<(usize, f32)> {
        view::topk(&self.data, k)
    }

    /// max |a - b| and max relative error vs `other`.
    pub fn max_abs_rel_diff(&self, other: &Tensor) -> Result<(f32, f32)> {
        if self.shape != other.shape {
            bail!("diff shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        }
        let mut abs = 0f32;
        let mut rel = 0f32;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            let d = (a - b).abs();
            abs = abs.max(d);
            let denom = a.abs().max(b.abs()).max(1e-12);
            rel = rel.max(d / denom);
        }
        Ok((abs, rel))
    }

    /// Load a raw little-endian f32 file written by aot.py.
    pub fn from_f32_file(path: &std::path::Path, shape: &[usize]) -> Result<Tensor> {
        let bytes = std::fs::read(path)?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!(
                "{}: expected {} bytes for shape {:?}, got {}",
                path.display(),
                n * 4,
                shape,
                bytes.len()
            );
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::random(&[3, 2], 1);
        let b = Tensor::random(&[3, 2], 2);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[2, 3, 2]);
        let parts = s.unstack().unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_rejects_mismatch() {
        let a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        assert!(Tensor::stack(&[&a, &b]).is_err());
        assert!(Tensor::stack(&[]).is_err());
    }

    #[test]
    fn argmax_and_topk() {
        let t = Tensor::new(&[5], vec![0.1, 0.9, 0.3, 0.9, 0.0]).unwrap();
        assert_eq!(t.argmax(), 1); // first max wins
        let tk = t.topk(3);
        assert_eq!(tk.len(), 3);
        assert_eq!(tk[0].1, 0.9);
        assert_eq!(tk[2], (2, 0.3));
    }

    #[test]
    fn topk_k_larger_than_len() {
        let t = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let tk = t.topk(5);
        assert_eq!(tk.len(), 2);
        assert_eq!(tk[0], (1, 2.0));
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2], vec![1.1, 2.0]).unwrap();
        let (abs, rel) = a.max_abs_rel_diff(&b).unwrap();
        assert!((abs - 0.1).abs() < 1e-6);
        assert!(rel > 0.0 && rel < 0.1);
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(Tensor::random(&[4], 9), Tensor::random(&[4], 9));
        assert_ne!(Tensor::random(&[4], 9), Tensor::random(&[4], 10));
    }
}
