//! In-tree substrates a framework would normally import: JSON, CLI
//! parsing, logging.  See DESIGN.md §Substitutions for why these are
//! hand-rolled (bare-metal dependency policy, matching the paper).

pub mod cli;
pub mod json;
pub mod log;
pub mod wire;

/// Duration -> milliseconds as f64 (the unit every report uses).
pub fn ms(d: std::time::Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile by nearest-rank on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentile() {
        let mut xs = vec![4.0, 1.0, 3.0, 2.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 5.0);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }
}
