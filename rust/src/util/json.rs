//! Minimal JSON parser/writer (serde is deliberately not a dependency).
//!
//! The paper's thesis is that embedded engines should carry no framework
//! baggage; this repo's Rust side follows suit and implements the little
//! JSON it needs (manifest.json, the wire protocol, config files) in ~300
//! lines.  Supports the full JSON grammar: objects, arrays, strings with
//! escapes (incl. `\uXXXX` + surrogate pairs), numbers, bools, null.
//!
//! Numbers are stored as `f64` (every value in our manifests fits exactly:
//! offsets < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Container nesting bound shared by this parser and the wire-path
/// scanner (`util::wire`).  The tree parser recurses per `[`/`{`, so an
/// unbounded depth would let one hostile request line overflow an IO
/// lane's stack; 64 is far beyond any manifest/config/request shape.
pub const MAX_DEPTH: usize = 64;

/// Parse error with byte offset for diagnostics.  Accessor errors
/// (missing key, wrong shape) have no meaningful byte offset — they
/// carry [`NO_POS`](JsonError::NO_POS) and render without one, instead
/// of the misleading `at byte 0` they used to report.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl JsonError {
    /// Sentinel for "no byte position" (post-parse accessor errors).
    pub const NO_POS: usize = usize::MAX;

    /// Accessor error: message only, no byte offset.
    fn ctx(msg: String) -> JsonError {
        JsonError { msg, pos: Self::NO_POS }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.pos == Self::NO_POS {
            write!(f, "json error: {}", self.msg)
        } else {
            write!(f, "json error at byte {}: {}", self.pos, self.msg)
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — builder use only).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Short shape description for error context.
    fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "a bool",
            Json::Num(_) => "a number",
            Json::Str(_) => "a string",
            Json::Arr(_) => "an array",
            Json::Obj(_) => "an object",
        }
    }

    /// Available keys, truncated — so a "missing key" error says what
    /// the document *does* contain (manifest/config diagnostics).
    fn keys_summary(&self) -> String {
        match self {
            Json::Obj(m) => {
                let mut keys: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
                let extra = keys.len().saturating_sub(8);
                keys.truncate(8);
                let mut s = keys.join(", ");
                if extra > 0 {
                    s.push_str(&format!(", ... {extra} more"));
                }
                s
            }
            _ => String::new(),
        }
    }

    /// `get` that treats missing key as an error (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| match self {
            Json::Obj(_) => JsonError::ctx(format!(
                "missing key '{key}' (object has: {})",
                self.keys_summary()
            )),
            other => JsonError::ctx(format!(
                "missing key '{key}': value is {}, not an object",
                other.type_name()
            )),
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `self[key]` as &str or error.
    pub fn str_of(&self, key: &str) -> Result<&str, JsonError> {
        let v = self.req(key)?;
        v.as_str().ok_or_else(|| {
            JsonError::ctx(format!(
                "key '{key}' is not a string (got {})",
                v.type_name()
            ))
        })
    }

    pub fn usize_of(&self, key: &str) -> Result<usize, JsonError> {
        let v = self.req(key)?;
        v.as_usize().ok_or_else(|| {
            JsonError::ctx(format!(
                "key '{key}' is not a non-negative integer (got {v:?})"
            ))
        })
    }

    pub fn f64_of(&self, key: &str) -> Result<f64, JsonError> {
        let v = self.req(key)?;
        v.as_f64().ok_or_else(|| {
            JsonError::ctx(format!(
                "key '{key}' is not a number (got {})",
                v.type_name()
            ))
        })
    }

    /// Array of usize under `key` (shape fields).
    pub fn shape_of(&self, key: &str) -> Result<Vec<usize>, JsonError> {
        let v = self.req(key)?;
        let arr = v.as_arr().ok_or_else(|| {
            JsonError::ctx(format!(
                "key '{key}' is not an array (got {})",
                v.type_name()
            ))
        })?;
        arr.iter()
            .enumerate()
            .map(|(i, v)| {
                v.as_usize().ok_or_else(|| {
                    JsonError::ctx(format!(
                        "'{key}[{i}]' is not a usize (got {v:?})"
                    ))
                })
            })
            .collect()
    }

    // ---- parse / serialize ------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Open-container count: `value()` recurses per `[`/`{`, so the
    /// depth must be bounded or a hostile line overflows the stack
    /// (the wire scanner shares `MAX_DEPTH` and rejects identically).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting exceeds depth limit"));
        }
        self.depth += 1;
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo • 日本\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo • 日本");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn shape_of_works() {
        let v = Json::parse(r#"{"shape": [7, 7, 3, 96]}"#).unwrap();
        assert_eq!(v.shape_of("shape").unwrap(), vec![7, 7, 3, 96]);
    }

    #[test]
    fn depth_is_bounded() {
        // Exactly MAX_DEPTH nested containers parse; one more is a
        // structured error, not a stack overflow.
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.msg.contains("depth"), "{e}");
        // A pathological line (way past any sane stack) still returns.
        let hostile = "[".repeat(200_000);
        assert!(Json::parse(&hostile).is_err());
        // Mixed nesting counts both container kinds.
        let mixed: String =
            "[{\"k\":".repeat(MAX_DEPTH) + "1" + &"}]".repeat(MAX_DEPTH);
        assert!(Json::parse(&mixed).is_err());
    }

    #[test]
    fn accessor_errors_carry_context_not_byte_zero() {
        let v = Json::parse(r#"{"name":"a","shape":[1,"x"],"n":-2}"#).unwrap();
        let e = v.req("missing").unwrap_err();
        assert_eq!(e.pos, JsonError::NO_POS);
        let text = e.to_string();
        assert!(!text.contains("at byte"), "{text}");
        assert!(text.contains("missing"), "{text}");
        assert!(text.contains("name"), "available keys listed: {text}");
        let e = v.str_of("n").unwrap_err();
        assert!(e.to_string().contains("a number"), "{e}");
        let e = v.usize_of("n").unwrap_err();
        assert!(e.to_string().contains("-2"), "{e}");
        let e = v.shape_of("shape").unwrap_err();
        assert!(e.to_string().contains("shape[1]"), "{e}");
        // Requesting a key on a non-object says so.
        let e = Json::Num(4.0).req("x").unwrap_err();
        assert!(e.to_string().contains("not an object"), "{e}");
        // Parse errors still carry a real byte offset.
        let e = Json::parse("{\"a\": nope}").unwrap_err();
        assert!(e.to_string().contains("at byte"), "{e}");
    }

    #[test]
    fn builder_roundtrip() {
        let mut o = Json::obj();
        o.set("name", "fire2".into())
            .set("count", 8usize.into())
            .set("ok", true.into());
        let parsed = Json::parse(&o.to_string()).unwrap();
        assert_eq!(parsed.str_of("name").unwrap(), "fire2");
        assert_eq!(parsed.usize_of("count").unwrap(), 8);
    }
}
