//! Leveled stderr logger with monotonic timestamps.
//!
//! One static atomic level; `log!`-style macros expand to a level check and
//! a single `eprintln!`, so disabled levels cost one atomic load on the
//! request path (the paper's engine keeps the hot loop lean; so do we).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

/// Process start, for relative timestamps.
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call (monotonic).
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        ERROR => "ERROR",
        WARN => "WARN ",
        INFO => "INFO ",
        _ => "DEBUG",
    };
    eprintln!("[{:9.3}] {} {}: {}", elapsed(), tag, target, msg);
}

/// Token-bucket rate limiter for WARN/ERROR lines on request-path
/// failure branches (shed, reject, at-capacity).  Under sustained
/// overload those branches fire per-request; unthrottled `eprintln!`
/// there turns the log into the bottleneck.  The bucket admits a burst
/// then refills at a steady rate; suppressed lines are counted and the
/// count is drained into the next admitted line (`suppressed_note`),
/// so no event disappears without a trace.
///
/// Lock-free: state is one packed u64 — high 32 bits the last-refill
/// timestamp (ms since process start), low 32 bits the current token
/// balance in millitokens — updated by compare-exchange.  A lost race
/// just retries; a suppressed call is a single `fetch_add`.
pub struct RateLimiter {
    /// `(last_refill_ms as u64) << 32 | millitokens`.
    state: AtomicU64,
    /// Drained (and reported) by the next admitted line.
    suppressed: AtomicU64,
    burst_millitokens: u32,
    refill_per_sec_millitokens: u32,
}

impl RateLimiter {
    /// A bucket admitting `burst` immediate lines, refilling at
    /// `per_sec` lines per second (const so statics need no lazy init).
    pub const fn new(burst: u32, per_sec: u32) -> Self {
        Self {
            state: AtomicU64::new((burst * 1000) as u64),
            suppressed: AtomicU64::new(0),
            burst_millitokens: burst * 1000,
            refill_per_sec_millitokens: per_sec * 1000,
        }
    }

    /// Try to take one token.  `Some(n)` admits the line and drains the
    /// count of lines suppressed since the last admitted one (render it
    /// with [`suppressed_note`]); `None` suppresses this line.
    pub fn allow(&self) -> Option<u64> {
        self.allow_at((elapsed() * 1000.0) as u64)
    }

    /// [`RateLimiter::allow`] against an explicit clock (ms on any
    /// monotonic scale) — the testable core.
    pub fn allow_at(&self, now_ms: u64) -> Option<u64> {
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            let last_ms = cur >> 32;
            let tokens = (cur & 0xffff_ffff) as u32;
            // Saturate the elapsed window so a huge gap can't overflow
            // the refill product; the balance caps at burst anyway.
            let dt_ms = now_ms.saturating_sub(last_ms).min(1 << 20) as u32;
            let refilled = (tokens as u64
                + dt_ms as u64 * self.refill_per_sec_millitokens as u64 / 1000)
                .min(self.burst_millitokens as u64) as u32;
            let (next_tokens, admit) = if refilled >= 1000 {
                (refilled - 1000, true)
            } else {
                (refilled, false)
            };
            let next = (now_ms.max(last_ms) << 32) | next_tokens as u64;
            match self.state.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    return if admit {
                        Some(self.suppressed.swap(0, Ordering::Relaxed))
                    } else {
                        self.suppressed.fetch_add(1, Ordering::Relaxed);
                        None
                    };
                }
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Shared limiter for shed/reject warns on the admission path.
pub static SHED_LOG: RateLimiter = RateLimiter::new(10, 2);

/// Shared limiter for connection-cap warns on the accept path.
pub static CAPACITY_LOG: RateLimiter = RateLimiter::new(10, 2);

/// Render a drained suppression count as a log suffix: empty for 0,
/// `" [17 suppressed]"` otherwise.
pub fn suppressed_note(n: u64) -> String {
    if n == 0 {
        String::new()
    } else {
        format!(" [{n} suppressed]")
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::INFO, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::WARN, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::ERROR, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::DEBUG, $target,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(WARN);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(!enabled(INFO));
        set_level(INFO);
        assert!(enabled(INFO));
        assert!(!enabled(DEBUG));
    }

    #[test]
    fn elapsed_is_monotonic() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }

    #[test]
    fn rate_limiter_admits_burst_then_throttles() {
        let rl = RateLimiter::new(3, 1);
        assert_eq!(rl.allow_at(0), Some(0));
        assert_eq!(rl.allow_at(0), Some(0));
        assert_eq!(rl.allow_at(0), Some(0));
        // Burst exhausted: everything at the same instant is dropped.
        for _ in 0..5 {
            assert_eq!(rl.allow_at(0), None);
        }
        // One second later one token has refilled, and the admitted
        // line drains the 5 suppressed ones.
        assert_eq!(rl.allow_at(1000), Some(5));
        assert_eq!(rl.allow_at(1000), None);
    }

    #[test]
    fn rate_limiter_refill_caps_at_burst() {
        let rl = RateLimiter::new(2, 10);
        assert_eq!(rl.allow_at(0), Some(0));
        assert_eq!(rl.allow_at(0), Some(0));
        assert_eq!(rl.allow_at(0), None);
        // A long idle gap refills to the cap (2), not per_sec × gap.
        let t = 3_600_000;
        assert_eq!(rl.allow_at(t), Some(1));
        assert_eq!(rl.allow_at(t), Some(0));
        assert_eq!(rl.allow_at(t), None);
    }

    #[test]
    fn rate_limiter_partial_refill() {
        let rl = RateLimiter::new(1, 2); // 2 tokens/sec = 1 per 500 ms
        assert_eq!(rl.allow_at(0), Some(0));
        assert_eq!(rl.allow_at(100), None); // only 0.2 tokens back
        assert_eq!(rl.allow_at(499), None);
        assert!(rl.allow_at(600).is_some());
    }

    #[test]
    fn rate_limiter_stale_clock_does_not_panic() {
        let rl = RateLimiter::new(1, 1);
        assert_eq!(rl.allow_at(5000), Some(0));
        // Clock going backwards (cross-thread skew) just sees an empty
        // elapsed window — no underflow, no token minting.
        assert_eq!(rl.allow_at(100), None);
        assert!(rl.allow_at(6500).is_some());
    }

    #[test]
    fn suppressed_note_formats() {
        assert_eq!(suppressed_note(0), "");
        assert_eq!(suppressed_note(17), " [17 suppressed]");
    }
}
