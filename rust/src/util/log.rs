//! Leveled stderr logger with monotonic timestamps.
//!
//! One static atomic level; `log!`-style macros expand to a level check and
//! a single `eprintln!`, so disabled levels cost one atomic load on the
//! request path (the paper's engine keeps the hot loop lean; so do we).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

/// Process start, for relative timestamps.
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call (monotonic).
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        ERROR => "ERROR",
        WARN => "WARN ",
        INFO => "INFO ",
        _ => "DEBUG",
    };
    eprintln!("[{:9.3}] {} {}: {}", elapsed(), tag, target, msg);
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::INFO, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::WARN, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::ERROR, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::DEBUG, $target,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(WARN);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(!enabled(INFO));
        set_level(INFO);
        assert!(enabled(INFO));
        assert!(!enabled(DEBUG));
    }

    #[test]
    fn elapsed_is_monotonic() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
