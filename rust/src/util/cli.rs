//! Tiny CLI argument parser (clap is deliberately not a dependency).
//!
//! Grammar: `zuluko <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted.  Unknown flags are an error, so typos
//! fail loudly instead of being silently ignored.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, key-values, positionals.
///
/// A flag may be given more than once; [`Args::get`] returns the last
/// occurrence (override semantics) and [`Args::get_all`] returns every
/// occurrence in order (list semantics, e.g. repeated `--model`).
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
    /// Flags the program declares; used to reject unknown ones.
    known: Vec<&'static str>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `known` lists every accepted `--name` (value-taking or boolean).
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        known: &[&'static str],
    ) -> Result<Args, String> {
        let mut args = Args {
            known: known.to_vec(),
            ..Args::default()
        };
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if !args.known.contains(&key.as_str()) {
                    return Err(format!("unknown flag --{key}"));
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // Take the next token as the value unless it looks
                        // like another flag (boolean-style usage).
                        match it.peek() {
                            Some(n) if !n.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.entry(key).or_default().push(val);
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env(known: &[&'static str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), known)
    }

    /// Last occurrence of `--key` (CLI override semantics).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of `--key`, in command-line order (for
    /// repeatable flags like `--model name=path`).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    const KNOWN: &[&'static str] = &["engine", "iters", "verbose", "rate"];

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(
            v(&["bench", "--engine", "acl", "--iters=30", "img.ppm"]),
            KNOWN,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("engine"), Some("acl"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 30);
        assert_eq!(a.positional, vec!["img.ppm"]);
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = Args::parse(v(&["serve", "--verbose", "--engine", "tf"]), KNOWN).unwrap();
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get("engine"), Some("tf"));
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(Args::parse(v(&["x", "--nope"]), KNOWN).is_err());
    }

    #[test]
    fn rejects_bad_int() {
        let a = Args::parse(v(&["x", "--iters", "abc"]), KNOWN).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }

    #[test]
    fn repeated_flag_keeps_all_and_get_returns_last() {
        let a = Args::parse(
            v(&["serve", "--engine", "acl", "--engine", "tf"]),
            KNOWN,
        )
        .unwrap();
        assert_eq!(a.get("engine"), Some("tf"));
        assert_eq!(a.get_all("engine"), vec!["acl", "tf"]);
        assert!(a.get_all("iters").is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(v(&["x"]), KNOWN).unwrap();
        assert_eq!(a.get_usize("iters", 7).unwrap(), 7);
        assert_eq!(a.get_or("engine", "acl"), "acl");
        assert_eq!(a.get_f64("rate", 1.5).unwrap(), 1.5);
    }
}
