//! Wire-path JSON scanner: an iterative, bounded-depth, no-panic tape
//! scanner for the request hot path (DESIGN.md "Wire plane").
//!
//! [`Json::parse`](super::json::Json::parse) materializes a full value
//! tree — a `BTreeMap` node, a `String` per key, and a `Json` per value
//! — for every request line, making the parser the last allocating
//! stage between socket and reply.  This module scans a line **in
//! place** instead: one forward pass validates the full JSON grammar
//! (same accept/reject behavior as the tree parser) and records a flat
//! tape of `(key span, value span, type)` byte offsets into the
//! connection's pooled read buffer.  A sparse extractor then pulls only
//! the fields the hot path needs (`id`, `cmd`, `model`, `deadline_ms`,
//! `priority`, the `image` spec) as borrowed `&str`/number views.
//!
//! Design rules:
//!
//! - **Iterative, bounded depth**: no recursion anywhere; container
//!   nesting uses a fixed `MAX_DEPTH`-slot frame array, so untrusted
//!   wire bytes can neither overflow an IO-lane stack nor allocate
//!   frames.  The legacy tree parser enforces the same bound.
//! - **No reachable panic**: all byte access goes through `get`; there
//!   is no indexing, `unwrap`, or unchecked arithmetic on the scan path.
//! - **Escape deferral**: string spans are recorded with a "contains a
//!   backslash" flag; decoding (the only allocating operation) happens
//!   only when an extracted field actually contains escapes.  The
//!   common request line borrows every field straight from the buffer.
//! - **Lossy-decode parity**: the serving planes feed the tree parser
//!   `String::from_utf8_lossy(line)`, where invalid UTF-8 inside
//!   strings becomes U+FFFD.  The scanner therefore accepts arbitrary
//!   non-control bytes inside strings and defers the same replacement
//!   to extraction, so both parsers accept/reject identical byte lines.
//!
//! The tree parser remains the right tool off the hot path (manifests,
//! config files, reply building) — see `util::json`.

use std::borrow::Cow;
use std::fmt;

use super::json::MAX_DEPTH;

/// Scan error with byte offset, mirroring
/// [`JsonError`](super::json::JsonError)'s display shape.  The message
/// is static: rejecting a line must not allocate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireError {
    pub msg: &'static str,
    pub pos: usize,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for WireError {}

/// Value type of a tape entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Null,
    Bool,
    Num,
    Str,
    Arr,
    Obj,
}

/// Sentinel for "no key" (the root value) and "no entry to patch"
/// (array-element containers).
const NONE_IDX: usize = usize::MAX;

/// One tape row: where a value (and its object key, if any) lives in
/// the scanned line.  `Str` spans exclude the quotes; `Num`/`Bool`/
/// `Null` spans cover the token; container spans include the brackets.
#[derive(Debug, Clone, Copy)]
struct Entry {
    key_start: usize,
    key_end: usize,
    key_escaped: bool,
    val_start: usize,
    val_end: usize,
    val_escaped: bool,
    kind: Kind,
    /// Container nesting depth of the value (root = 0, top-level object
    /// members = 1, `image`'s members = 2, ...).
    depth: usize,
}

/// Reusable tape scratch.  One lives per IO lane / connection loop; the
/// entry vector's capacity is retained across requests, so steady-state
/// scans allocate nothing.
#[derive(Default)]
pub struct WireTape {
    entries: Vec<Entry>,
}

impl WireTape {
    pub fn new() -> WireTape {
        WireTape::default()
    }
}

/// A scanned line: borrowed view over the raw bytes plus the tape.
pub struct WireDoc<'b> {
    bytes: &'b [u8],
    entries: &'b [Entry],
}

/// Handle to one tape entry (index into the tape).
#[derive(Debug, Clone, Copy)]
pub struct Fld(usize);

// ---------------------------------------------------------------------------
// Scanner
// ---------------------------------------------------------------------------

struct Scanner<'b> {
    bytes: &'b [u8],
    pos: usize,
}

impl Scanner<'_> {
    fn err(&self, msg: &'static str) -> WireError {
        WireError { msg, pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn literal(&mut self, lit: &'static [u8]) -> Result<(), WireError> {
        if self.bytes.get(self.pos..).is_some_and(|r| r.starts_with(lit)) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err("expected a JSON value"))
        }
    }

    /// `"key" :` with surrounding whitespace; leaves the cursor at the
    /// member's value.  Returns the key's inner span + escape flag.
    fn scan_key(&mut self) -> Result<(usize, usize, bool), WireError> {
        self.skip_ws();
        let key = self.scan_string()?;
        self.skip_ws();
        if self.bump() != Some(b':') {
            self.pos = self.pos.saturating_sub(1);
            return Err(self.err("expected ':'"));
        }
        self.skip_ws();
        Ok(key)
    }

    /// Validate a string token; returns `(start, end, has_escapes)` for
    /// the span between the quotes.  Bytes >= 0x20 other than `"`/`\`
    /// pass through unexamined (see the lossy-decode parity rule in the
    /// module docs); escape sequences are validated here so accept and
    /// reject decisions never wait for (deferred) decoding.
    fn scan_string(&mut self) -> Result<(usize, usize, bool), WireError> {
        if self.bump() != Some(b'"') {
            self.pos = self.pos.saturating_sub(1);
            return Err(self.err("expected '\"'"));
        }
        let start = self.pos;
        let mut escaped = false;
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok((start, self.pos - 1, escaped)),
                Some(b'\\') => {
                    escaped = true;
                    self.escape()?;
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(_) => {}
            }
        }
    }

    /// Validate one escape sequence (cursor just past the backslash).
    fn escape(&mut self) -> Result<(), WireError> {
        match self.bump() {
            Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => Ok(()),
            Some(b'u') => {
                let hi = self.hex4()?;
                if (0xD800..0xDC00).contains(&hi) {
                    // High surrogate: a low surrogate escape must follow.
                    if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                        return Err(self.err("unpaired surrogate"));
                    }
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err("invalid low surrogate"));
                    }
                    // Any paired value lands in 0x10000..=0x10FFFF: valid.
                    Ok(())
                } else if char::from_u32(hi).is_some() {
                    Ok(())
                } else {
                    // Lone low surrogate.
                    Err(self.err("invalid codepoint"))
                }
            }
            _ => Err(self.err("bad escape")),
        }
    }

    fn hex4(&mut self) -> Result<u32, WireError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    /// Consume a number token with the same lax prefix grammar as the
    /// tree parser, then validate it with the same `f64` parse (so
    /// oddities like `1e309` -> inf agree between parsers).
    fn scan_number(&mut self) -> Result<usize, WireError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let token = self.bytes.get(start..self.pos).unwrap_or(&[]);
        match std::str::from_utf8(token).ok().and_then(|t| t.parse::<f64>().ok()) {
            Some(_) => Ok(self.pos),
            None => Err(self.err("bad number")),
        }
    }
}

/// Scan one line into `tape`, reusing its storage.  The whole line must
/// be a single JSON value (trailing bytes reject, like the tree parser);
/// callers trim with [`trim_ws`] first.
pub fn scan<'b>(
    bytes: &'b [u8],
    tape: &'b mut WireTape,
) -> Result<WireDoc<'b>, WireError> {
    tape.entries.clear();
    let mut s = Scanner { bytes, pos: 0 };
    // Open containers: (is_object, tape index to patch on close —
    // NONE_IDX for array-element containers, which get no tape row).
    let mut frames = [(false, NONE_IDX); MAX_DEPTH];
    let mut depth = 0usize;
    // Key span of the member value about to be scanned, NONE_IDX-keyed
    // for root / array elements.
    let mut key: (usize, usize, bool) = (NONE_IDX, 0, false);
    s.skip_ws();
    let mut at_value = true;
    loop {
        if at_value {
            // ---- scan one value starting at the cursor ----------------
            let val_start = s.pos;
            let (key_start, key_end, key_escaped) = key;
            // Tape rows: the root value and every object member.  Array
            // elements are grammar-validated but not recorded — nothing
            // on the hot path extracts them.
            let record = key_start != NONE_IDX || depth == 0;
            key = (NONE_IDX, 0, false);
            match s.peek() {
                Some(open @ (b'{' | b'[')) => {
                    let is_obj = open == b'{';
                    if depth == MAX_DEPTH {
                        return Err(s.err("nesting exceeds depth limit"));
                    }
                    s.pos += 1;
                    let entry = if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start,
                            val_end: 0, // patched when the container closes
                            val_escaped: false,
                            kind: if is_obj { Kind::Obj } else { Kind::Arr },
                            depth,
                        });
                        tape.entries.len() - 1
                    } else {
                        NONE_IDX
                    };
                    if let Some(f) = frames.get_mut(depth) {
                        *f = (is_obj, entry);
                    }
                    depth += 1;
                    s.skip_ws();
                    let close = if is_obj { b'}' } else { b']' };
                    if s.peek() == Some(close) {
                        s.pos += 1;
                        depth -= 1;
                        if let Some(e) = tape.entries.get_mut(entry) {
                            e.val_end = s.pos;
                        }
                        at_value = false;
                    } else if is_obj {
                        key = s.scan_key()?;
                        // at_value stays true: scan the member's value.
                    }
                    // Non-empty array: at_value stays true, key stays
                    // unset; the next iteration scans the first element.
                }
                Some(b'"') => {
                    let (st, en, esc) = s.scan_string()?;
                    if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start: st,
                            val_end: en,
                            val_escaped: esc,
                            kind: Kind::Str,
                            depth,
                        });
                    }
                    at_value = false;
                }
                Some(b't') => {
                    s.literal(b"true")?;
                    if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start,
                            val_end: s.pos,
                            val_escaped: false,
                            kind: Kind::Bool,
                            depth,
                        });
                    }
                    at_value = false;
                }
                Some(b'f') => {
                    s.literal(b"false")?;
                    if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start,
                            val_end: s.pos,
                            val_escaped: false,
                            kind: Kind::Bool,
                            depth,
                        });
                    }
                    at_value = false;
                }
                Some(b'n') => {
                    s.literal(b"null")?;
                    if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start,
                            val_end: s.pos,
                            val_escaped: false,
                            kind: Kind::Null,
                            depth,
                        });
                    }
                    at_value = false;
                }
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    let end = s.scan_number()?;
                    if record {
                        tape.entries.push(Entry {
                            key_start,
                            key_end,
                            key_escaped,
                            val_start,
                            val_end: end,
                            val_escaped: false,
                            kind: Kind::Num,
                            depth,
                        });
                    }
                    at_value = false;
                }
                _ => return Err(s.err("expected a JSON value")),
            }
        } else {
            // ---- a value at `depth` just completed --------------------
            if depth == 0 {
                s.skip_ws();
                if s.pos != s.bytes.len() {
                    return Err(s.err("trailing characters"));
                }
                return Ok(WireDoc { bytes, entries: &tape.entries });
            }
            let (is_obj, entry) =
                frames.get(depth - 1).copied().unwrap_or((false, NONE_IDX));
            s.skip_ws();
            match (is_obj, s.bump()) {
                (true, Some(b',')) => {
                    key = s.scan_key()?;
                    at_value = true;
                }
                (false, Some(b',')) => {
                    s.skip_ws();
                    at_value = true;
                }
                (true, Some(b'}')) | (false, Some(b']')) => {
                    depth -= 1;
                    if let Some(e) = tape.entries.get_mut(entry) {
                        e.val_end = s.pos;
                    }
                }
                (true, _) => return Err(s.err("expected ',' or '}'")),
                (false, _) => return Err(s.err("expected ',' or ']'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sparse extractor
// ---------------------------------------------------------------------------

impl<'b> WireDoc<'b> {
    pub fn root_is_object(&self) -> bool {
        matches!(self.entries.first(), Some(e) if e.depth == 0 && e.kind == Kind::Obj)
    }

    /// Last top-level member named `name` — last-wins on duplicate keys,
    /// matching the tree parser's `BTreeMap` insert.  `None` when the
    /// root is not an object (same as `Json::get` on a non-object).
    pub fn get(&self, name: &str) -> Option<Fld> {
        // Depth-1 entries exist only under an object root, so no
        // explicit root-kind guard is needed.
        self.find(1, 0, self.entries.len(), name)
    }

    /// Last direct member of the object `parent` named `name`.
    pub fn child(&self, parent: Fld, name: &str) -> Option<Fld> {
        let e = self.entries.get(parent.0)?;
        if e.kind != Kind::Obj {
            return None;
        }
        // Members follow their container on the tape until the first
        // entry at the container's depth or shallower.
        let from = parent.0 + 1;
        let mut to = from;
        while let Some(n) = self.entries.get(to) {
            if n.depth <= e.depth {
                break;
            }
            to += 1;
        }
        self.find(e.depth + 1, from, to, name)
    }

    fn find(&self, depth: usize, from: usize, to: usize, name: &str) -> Option<Fld> {
        let mut found = None;
        for (i, e) in self.entries.iter().enumerate().take(to).skip(from) {
            if e.depth == depth && e.key_start != NONE_IDX && self.key_eq(e, name) {
                found = Some(Fld(i));
            }
        }
        found
    }

    fn key_eq(&self, e: &Entry, name: &str) -> bool {
        let raw = self.bytes.get(e.key_start..e.key_end).unwrap_or(&[]);
        if !e.key_escaped {
            return raw == name.as_bytes();
        }
        // Rare: a key spelled with escapes — decode (allocates) and
        // compare text, so `{"\u0069d":1}` still finds "id".
        decode_cow(raw, true) == name
    }

    pub fn kind(&self, f: Fld) -> Kind {
        self.entries.get(f.0).map_or(Kind::Null, |e| e.kind)
    }

    /// Byte offset of the value, for diagnostics.
    pub fn pos(&self, f: Fld) -> usize {
        self.entries.get(f.0).map_or(0, |e| e.val_start)
    }

    /// Raw value span (string spans exclude the quotes).
    pub fn raw(&self, f: Fld) -> &'b [u8] {
        self.entries
            .get(f.0)
            .and_then(|e| self.bytes.get(e.val_start..e.val_end))
            .unwrap_or(&[])
    }

    /// String view: borrowed straight from the buffer unless the span
    /// contains escapes (decode) or invalid UTF-8 (lossy replacement,
    /// matching what the tree parser sees after `from_utf8_lossy`).
    pub fn str_value(&self, f: Fld) -> Option<Cow<'b, str>> {
        let e = self.entries.get(f.0)?;
        if e.kind != Kind::Str {
            return None;
        }
        let raw = self.bytes.get(e.val_start..e.val_end)?;
        Some(decode_cow(raw, e.val_escaped))
    }

    pub fn f64_value(&self, f: Fld) -> Option<f64> {
        let e = self.entries.get(f.0)?;
        if e.kind != Kind::Num {
            return None;
        }
        let raw = self.bytes.get(e.val_start..e.val_end)?;
        std::str::from_utf8(raw).ok()?.parse().ok()
    }

    /// Mirror of `Json::as_usize`: non-negative, integer-valued.
    pub fn usize_value(&self, f: Fld) -> Option<usize> {
        self.f64_value(f).and_then(|v| {
            if v >= 0.0 && v.fract() == 0.0 {
                Some(v as usize)
            } else {
                None
            }
        })
    }

    pub fn bool_value(&self, f: Fld) -> Option<bool> {
        let e = self.entries.get(f.0)?;
        if e.kind != Kind::Bool {
            return None;
        }
        Some(self.bytes.get(e.val_start..e.val_end) == Some(b"true".as_ref()))
    }
}

// ---------------------------------------------------------------------------
// Deferred string decoding
// ---------------------------------------------------------------------------

/// Decode a validated string span.  Escape-free spans borrow (the
/// overwhelmingly common case); spans with escapes decode into an owned
/// string.  Invalid UTF-8 becomes U+FFFD either way — identical to the
/// lossy decode the tree path applies to the whole line (escape
/// sequences are pure ASCII and escape outputs are valid UTF-8, so
/// unescape and lossy replacement commute).
fn decode_cow(raw: &[u8], escaped: bool) -> Cow<'_, str> {
    if !escaped {
        return String::from_utf8_lossy(raw);
    }
    Cow::Owned(decode_escaped(raw))
}

fn decode_escaped(raw: &[u8]) -> String {
    let mut out: Vec<u8> = Vec::with_capacity(raw.len());
    let mut i = 0usize;
    while let Some(&b) = raw.get(i) {
        if b != b'\\' {
            out.push(b);
            i += 1;
            continue;
        }
        i += 1;
        match raw.get(i).copied() {
            Some(b'"') => {
                out.push(b'"');
                i += 1;
            }
            Some(b'\\') => {
                out.push(b'\\');
                i += 1;
            }
            Some(b'/') => {
                out.push(b'/');
                i += 1;
            }
            Some(b'b') => {
                out.push(0x08);
                i += 1;
            }
            Some(b'f') => {
                out.push(0x0C);
                i += 1;
            }
            Some(b'n') => {
                out.push(b'\n');
                i += 1;
            }
            Some(b'r') => {
                out.push(b'\r');
                i += 1;
            }
            Some(b't') => {
                out.push(b'\t');
                i += 1;
            }
            Some(b'u') => {
                i += 1;
                // The scan already validated hex digits and surrogate
                // pairing; the fallbacks below are defensive only.
                let mut cp = hex4_at(raw, i).unwrap_or(0xFFFD);
                let mut adv = 4usize;
                if (0xD800..0xDC00).contains(&cp) {
                    let paired = raw.get(i + 4) == Some(&b'\\')
                        && raw.get(i + 5) == Some(&b'u');
                    match hex4_at(raw, i + 6) {
                        Some(lo) if paired && (0xDC00..0xE000).contains(&lo) => {
                            cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            adv = 10;
                        }
                        _ => cp = 0xFFFD,
                    }
                }
                let ch = char::from_u32(cp).unwrap_or('\u{FFFD}');
                let mut buf = [0u8; 4];
                out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                i += adv;
            }
            _ => {
                // Unreachable after a successful scan; keep the byte.
                out.push(b'\\');
            }
        }
    }
    match String::from_utf8(out) {
        Ok(s) => s,
        Err(e) => String::from_utf8_lossy(e.as_bytes()).into_owned(),
    }
}

fn hex4_at(raw: &[u8], i: usize) -> Option<u32> {
    let mut v = 0u32;
    for k in 0..4 {
        let d = (*raw.get(i + k)? as char).to_digit(16)?;
        v = v * 16 + d;
    }
    Some(v)
}

// ---------------------------------------------------------------------------
// Line trimming
// ---------------------------------------------------------------------------

/// Byte-level equivalent of `str::trim()` on the lossy-decoded line
/// (the tree path trims Unicode whitespace; parity demands the same
/// here).  Invalid UTF-8 at an edge stops trimming — lossy decoding
/// would turn it into U+FFFD, which is not whitespace.
pub fn trim_ws(bytes: &[u8]) -> &[u8] {
    let mut b = bytes;
    while let Some(n) = leading_ws(b) {
        b = b.get(n..).unwrap_or(&[]);
    }
    while let Some(n) = trailing_ws(b) {
        b = b.get(..b.len().saturating_sub(n)).unwrap_or(&[]);
    }
    b
}

/// Whether the line is whitespace-only (the planes skip such lines
/// silently — `str::trim().is_empty()` parity).
pub fn is_blank(bytes: &[u8]) -> bool {
    trim_ws(bytes).is_empty()
}

fn leading_ws(b: &[u8]) -> Option<usize> {
    let &first = b.first()?;
    if first < 0x80 {
        return if (first as char).is_whitespace() { Some(1) } else { None };
    }
    // Multibyte: decode the first char; whitespace only if valid UTF-8.
    for len in 2..=4usize.min(b.len()) {
        if let Ok(s) = std::str::from_utf8(b.get(..len)?) {
            return match s.chars().next() {
                Some(c) if c.is_whitespace() => Some(len),
                _ => None,
            };
        }
    }
    None
}

fn trailing_ws(b: &[u8]) -> Option<usize> {
    let &last = b.last()?;
    if last < 0x80 {
        return if (last as char).is_whitespace() { Some(1) } else { None };
    }
    // Walk back to the lead byte of the trailing sequence (<= 4 bytes).
    for back in 2..=4usize.min(b.len()) {
        let idx = b.len() - back;
        let &lead = b.get(idx)?;
        if (0x80..0xC0).contains(&lead) {
            continue; // continuation byte, keep walking
        }
        return match std::str::from_utf8(b.get(idx..)?) {
            Ok(s) => {
                let mut chars = s.chars();
                match (chars.next(), chars.next()) {
                    (Some(c), None) if c.is_whitespace() => Some(back),
                    _ => None,
                }
            }
            Err(_) => None,
        };
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_ok<'b>(bytes: &'b [u8], tape: &'b mut WireTape) -> WireDoc<'b> {
        match scan(bytes, tape) {
            Ok(d) => d,
            Err(e) => panic!("scan failed on {:?}: {e}", String::from_utf8_lossy(bytes)),
        }
    }

    #[test]
    fn scans_a_request_line_and_extracts_fields() {
        let line = br#"{"id":7,"image":{"synthetic":42},"deadline_ms":250.5,"priority":"hi"}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        assert!(doc.root_is_object());
        let id = doc.get("id").expect("id");
        assert_eq!(doc.kind(id), Kind::Num);
        assert_eq!(doc.usize_value(id), Some(7));
        let img = doc.get("image").expect("image");
        assert_eq!(doc.kind(img), Kind::Obj);
        let syn = doc.child(img, "synthetic").expect("synthetic");
        assert_eq!(doc.f64_value(syn), Some(42.0));
        assert_eq!(doc.raw(syn), b"42");
        let dl = doc.get("deadline_ms").expect("deadline");
        assert_eq!(doc.f64_value(dl), Some(250.5));
        let pr = doc.get("priority").expect("priority");
        assert_eq!(doc.str_value(pr).as_deref(), Some("hi"));
        assert!(doc.get("model").is_none());
    }

    #[test]
    fn borrowed_strings_do_not_decode() {
        let line = br#"{"model":"squeezenet-v2"}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        let m = doc.get("model").expect("model");
        match doc.str_value(m) {
            Some(Cow::Borrowed(s)) => assert_eq!(s, "squeezenet-v2"),
            other => panic!("expected borrowed view, got {other:?}"),
        }
    }

    #[test]
    fn escaped_strings_decode_on_extraction() {
        let line = br#"{"model":"a\nb\u0041\ud83d\ude00"}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        let m = doc.get("model").expect("model");
        match doc.str_value(m) {
            Some(Cow::Owned(s)) => assert_eq!(s, "a\nbA😀"),
            other => panic!("expected owned decode, got {other:?}"),
        }
    }

    #[test]
    fn escaped_keys_still_match() {
        let line = br#"{"\u0069d": 9}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        let id = doc.get("id").expect("escaped key should match 'id'");
        assert_eq!(doc.usize_value(id), Some(9));
    }

    #[test]
    fn duplicate_keys_are_last_wins() {
        let line = br#"{"id":1,"id":2}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        assert_eq!(doc.usize_value(doc.get("id").expect("id")), Some(2));
    }

    #[test]
    fn nested_keys_do_not_shadow_top_level() {
        // "synthetic" inside an array-nested object must not satisfy a
        // top-level or image-child lookup.
        let line = br#"{"a":[{"synthetic":5}],"image":{"ppm":"/x.ppm"}}"#;
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        assert!(doc.get("synthetic").is_none());
        let img = doc.get("image").expect("image");
        assert!(doc.child(img, "synthetic").is_none());
        assert_eq!(
            doc.child(img, "ppm").and_then(|f| doc.str_value(f)).as_deref(),
            Some("/x.ppm")
        );
    }

    #[test]
    fn depth_is_bounded_iteratively() {
        // MAX_DEPTH nested arrays scan fine; one more is a structured
        // reject (never a stack overflow — the scanner has no recursion).
        let ok = "[".repeat(MAX_DEPTH - 1) + &"]".repeat(MAX_DEPTH - 1);
        let mut tape = WireTape::new();
        assert!(scan(ok.as_bytes(), &mut tape).is_ok());
        let deep = "[".repeat(MAX_DEPTH + 1);
        let err = scan(deep.as_bytes(), &mut tape).expect_err("too deep");
        assert_eq!(err.msg, "nesting exceeds depth limit");
        let wide = "[".repeat(100_000);
        assert!(scan(wide.as_bytes(), &mut tape).is_err());
    }

    #[test]
    fn rejects_malformed_inputs_without_panicking() {
        let cases: &[&[u8]] = &[
            b"",
            b"{",
            b"}",
            b"[1,]",
            b"{\"a\":}",
            b"{\"a\" 1}",
            b"tru",
            b"1 2",
            b"\"\\q\"",
            b"\"\\u12\"",
            b"\"\\ud800x\"",
            b"\"\\ud800\\u0041\"",
            b"\"unterminated",
            b"{\"id\":-}",
            b"nul",
            b"\x01",
            b"{\"a\":1,}",
        ];
        let mut tape = WireTape::new();
        for c in cases {
            assert!(
                scan(c, &mut tape).is_err(),
                "expected reject: {:?}",
                String::from_utf8_lossy(c)
            );
        }
    }

    #[test]
    fn accepts_grammar_corners_like_the_tree_parser() {
        // Keep in lockstep with util::json: lax number prefixes that
        // f64::parse accepts, big exponents -> inf, empty containers.
        let cases: &[&[u8]] = &[
            b"{}",
            b"[]",
            b"[[]]",
            b"0",
            b"-0",
            b"1.",
            b"01",
            b"1e309",
            b"[1,2,3]",
            b"{\"a\":{\"b\":{\"c\":null}}}",
            b"  {\"a\":1}  ",
        ];
        let mut tape = WireTape::new();
        for c in cases {
            assert!(
                scan(trim_ws(c), &mut tape).is_ok(),
                "expected accept: {:?}",
                String::from_utf8_lossy(c)
            );
        }
    }

    #[test]
    fn invalid_utf8_in_strings_matches_lossy_tree_behavior() {
        // A raw 0xFF inside a string: the planes' lossy decode gives the
        // tree parser U+FFFD; the scanner accepts the byte and defers
        // the same replacement to extraction.
        let line = b"{\"model\":\"a\xffb\"}";
        let mut tape = WireTape::new();
        let doc = scan_ok(line, &mut tape);
        let m = doc.get("model").expect("model");
        assert_eq!(doc.str_value(m).as_deref(), Some("a\u{FFFD}b"));
    }

    #[test]
    fn trim_ws_matches_str_trim() {
        let cases: &[&str] = &[
            "  {\"a\":1} \t\r\n",
            "\u{a0}{\"a\":1}\u{2028}",
            "   ",
            "",
            "x",
            "\u{3000}x\u{3000}",
        ];
        for c in cases {
            assert_eq!(
                trim_ws(c.as_bytes()),
                c.trim().as_bytes(),
                "trim parity on {c:?}"
            );
        }
        // Invalid UTF-8 at the edge stops trimming (lossy -> U+FFFD).
        assert_eq!(trim_ws(b" \xff "), b"\xff");
    }

    #[test]
    fn tape_is_reused_across_scans() {
        let mut tape = WireTape::new();
        for i in 0..32 {
            let line = format!("{{\"id\":{i},\"image\":{{\"synthetic\":{i}}}}}");
            let doc = scan_ok(line.as_bytes(), &mut tape);
            assert_eq!(doc.usize_value(doc.get("id").expect("id")), Some(i));
        }
    }
}
