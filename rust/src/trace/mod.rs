//! Workload generator / trace replay — the load side of every serving
//! experiment (E7/E8).
//!
//! Three arrival patterns:
//! * `Poisson { rate }` — open-loop with exponential gaps (IoT sensor
//!   fleet pushing frames);
//! * `ClosedLoop { concurrency }` — N clients, next request on response
//!   (the paper's own latency measurement loop is closed-loop with N=1);
//! * `Burst { size, gap }` — camera-burst pattern, stresses the batcher.
//!
//! Traces are deterministic per seed and can be saved/loaded as JSON for
//! replaying identical load across engines.

use anyhow::{bail, Context, Result};
use std::time::Duration;

use crate::testkit::rng::Rng;
use crate::util::json::Json;

/// Arrival pattern.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    Poisson { rate: f64 },
    ClosedLoop { concurrency: usize },
    Burst { size: usize, gap: Duration },
}

/// A workload: arrivals + per-request image seeds.
#[derive(Debug, Clone)]
pub struct Trace {
    pub pattern: Pattern,
    pub n_requests: usize,
    pub seed: u64,
    /// Arrival offsets from t0 (empty for closed-loop: arrivals are
    /// response-driven).
    pub arrivals: Vec<Duration>,
    /// Seed for each request's synthetic image.
    pub image_seeds: Vec<u64>,
}

impl Trace {
    /// Generate a deterministic trace.
    pub fn generate(pattern: Pattern, n_requests: usize, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let arrivals = match pattern {
            Pattern::Poisson { rate } => {
                let mut t = 0.0f64;
                (0..n_requests)
                    .map(|_| {
                        t += rng.exp_gap_secs(rate);
                        Duration::from_secs_f64(t)
                    })
                    .collect()
            }
            Pattern::ClosedLoop { .. } => Vec::new(),
            Pattern::Burst { size, gap } => (0..n_requests)
                .map(|i| gap * (i / size.max(1)) as u32)
                .collect(),
        };
        let image_seeds = (0..n_requests).map(|_| rng.next_u64()).collect();
        Trace {
            pattern,
            n_requests,
            seed,
            arrivals,
            image_seeds,
        }
    }

    /// Offered load in requests/sec (None for closed-loop).
    pub fn offered_rps(&self) -> Option<f64> {
        match self.pattern {
            Pattern::Poisson { rate } => Some(rate),
            Pattern::Burst { size, gap } => {
                if gap.is_zero() {
                    None
                } else {
                    Some(size as f64 / gap.as_secs_f64())
                }
            }
            Pattern::ClosedLoop { .. } => None,
        }
    }

    // ---- JSON persistence (replay identical load across engines) -------

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self.pattern {
            Pattern::Poisson { rate } => {
                o.set("pattern", "poisson".into()).set("rate", rate.into());
            }
            Pattern::ClosedLoop { concurrency } => {
                o.set("pattern", "closed".into())
                    .set("concurrency", concurrency.into());
            }
            Pattern::Burst { size, gap } => {
                o.set("pattern", "burst".into())
                    .set("size", size.into())
                    .set("gap_ms", (gap.as_secs_f64() * 1e3).into());
            }
        }
        o.set("n_requests", self.n_requests.into())
            .set("seed", self.seed.into())
            .set(
                "arrivals_ns",
                // ns as f64 is exact below 2^53 ns (~104 days) — plenty.
                Json::Arr(
                    self.arrivals
                        .iter()
                        .map(|d| Json::Num(d.as_nanos() as f64))
                        .collect(),
                ),
            )
            .set(
                "image_seeds",
                // u64 doesn't fit f64 exactly; serialize as strings.
                Json::Arr(
                    self.image_seeds
                        .iter()
                        .map(|&s| Json::Str(s.to_string()))
                        .collect(),
                ),
            );
        o
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let pattern = match j.str_of("pattern").map_err(|e| anyhow::anyhow!("{e}"))? {
            "poisson" => Pattern::Poisson {
                rate: j.f64_of("rate").map_err(|e| anyhow::anyhow!("{e}"))?,
            },
            "closed" => Pattern::ClosedLoop {
                concurrency: j
                    .usize_of("concurrency")
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
            },
            "burst" => Pattern::Burst {
                size: j.usize_of("size").map_err(|e| anyhow::anyhow!("{e}"))?,
                gap: Duration::from_secs_f64(
                    j.f64_of("gap_ms").map_err(|e| anyhow::anyhow!("{e}"))? / 1e3,
                ),
            },
            other => bail!("unknown pattern {other}"),
        };
        let arrivals = j
            .req("arrivals_ns")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("arrivals_ns")?
            .iter()
            .map(|v| Duration::from_nanos(v.as_f64().unwrap_or(0.0) as u64))
            .collect();
        let image_seeds = j
            .req("image_seeds")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("image_seeds")?
            .iter()
            .map(|v| match v {
                Json::Str(s) => s.parse().unwrap_or(0),
                _ => v.as_f64().unwrap_or(0.0) as u64,
            })
            .collect();
        Ok(Trace {
            pattern,
            n_requests: j
                .usize_of("n_requests")
                .map_err(|e| anyhow::anyhow!("{e}"))?,
            seed: j.usize_of("seed").map_err(|e| anyhow::anyhow!("{e}"))? as u64,
            arrivals,
            image_seeds,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Trace::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_monotonic_and_rate_ish() {
        let t = Trace::generate(Pattern::Poisson { rate: 100.0 }, 2000, 7);
        assert_eq!(t.arrivals.len(), 2000);
        for w in t.arrivals.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // Mean gap ~ 10ms within 20%.
        let total = t.arrivals.last().unwrap().as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 100.0).abs() < 20.0, "observed rate {rate}");
    }

    #[test]
    fn burst_pattern_groups_arrivals() {
        let t = Trace::generate(
            Pattern::Burst {
                size: 4,
                gap: Duration::from_millis(100),
            },
            8,
            1,
        );
        assert_eq!(t.arrivals[0], t.arrivals[3]); // same burst
        assert_eq!(t.arrivals[4], Duration::from_millis(100));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Trace::generate(Pattern::Poisson { rate: 10.0 }, 50, 3);
        let b = Trace::generate(Pattern::Poisson { rate: 10.0 }, 50, 3);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.image_seeds, b.image_seeds);
    }

    #[test]
    fn json_roundtrip() {
        for p in [
            Pattern::Poisson { rate: 25.0 },
            Pattern::ClosedLoop { concurrency: 4 },
            Pattern::Burst {
                size: 3,
                gap: Duration::from_millis(50),
            },
        ] {
            let t = Trace::generate(p, 20, 9);
            let back = Trace::from_json(&t.to_json()).unwrap();
            assert_eq!(back.pattern, t.pattern);
            assert_eq!(back.arrivals, t.arrivals);
            assert_eq!(back.image_seeds, t.image_seeds);
        }
    }

    #[test]
    fn offered_rps() {
        assert_eq!(
            Trace::generate(Pattern::Poisson { rate: 5.0 }, 1, 0).offered_rps(),
            Some(5.0)
        );
        assert_eq!(
            Trace::generate(Pattern::ClosedLoop { concurrency: 2 }, 1, 0)
                .offered_rps(),
            None
        );
    }
}
