//! Property tests for the tensor arena (DESIGN.md §"Memory ownership on
//! the hot path"): leases always come home (including across panics),
//! the per-class bound is hard, the pool survives concurrent worker
//! traffic, and the zero-copy view path is observationally identical to
//! the old owned `unstack` path.

use std::panic::AssertUnwindSafe;

use zuluko::tensor::{view, PooledTensor, Tensor, TensorPool};
use zuluko::testkit::prop::{prop_check, Gen, GenPair, GenUsize};
use zuluko::testkit::rng::Rng;

// ---------------------------------------------------------------------------
// Lease lifecycle
// ---------------------------------------------------------------------------

#[test]
fn every_lease_returns_on_drop() {
    prop_check(
        100,
        31,
        GenPair(GenUsize { lo: 1, hi: 8 }, GenUsize { lo: 1, hi: 20 }),
        |(cap, n)| {
            let pool = TensorPool::new(*cap);
            for _ in 0..*n {
                let _l = pool.lease(16);
            }
            let s = pool.stats();
            // Sequential lease/drop: after the first miss every lease is
            // a hit on the same returned buffer.
            if s.returned != *n as u64 {
                return Err(format!("returned {} of {n} leases", s.returned));
            }
            if s.buffers != 1 {
                return Err(format!("expected 1 shelved buffer, got {}", s.buffers));
            }
            if s.hits + s.misses != *n as u64 {
                return Err("lease accounting mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn lease_returns_to_pool_across_panic() {
    let pool = TensorPool::new(4);
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let _lease = pool.lease(32);
        panic!("request handler blew up");
    }));
    assert!(result.is_err(), "panic must propagate");
    let s = pool.stats();
    assert_eq!(s.returned, 1, "unwind must return the lease");
    assert_eq!(s.buffers, 1);
    // And the recovered buffer is immediately reusable.
    let l = pool.lease(32);
    assert_eq!(l.len(), 32);
    assert_eq!(pool.stats().hits, 1);
}

#[test]
fn pooled_tensor_returns_its_buffer_on_error_paths() {
    let pool = TensorPool::new(4);
    // Shape mismatch: PooledTensor::new fails, but the lease it consumed
    // still comes home via Drop.
    assert!(PooledTensor::new(&[3, 3], pool.lease(8)).is_err());
    assert_eq!(pool.stats().returned, 1);
}

// ---------------------------------------------------------------------------
// Bound
// ---------------------------------------------------------------------------

#[test]
fn per_class_bound_is_hard_under_random_traffic() {
    struct GenTraffic;
    impl Gen for GenTraffic {
        // (cap, ops): op = (size_class_selector, hold_or_drop)
        type Value = (usize, Vec<usize>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let cap = rng.range(1, 6);
            let n = rng.range(0, 60);
            (cap, (0..n).map(|_| rng.below(6)).collect())
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out = Vec::new();
            if !v.1.is_empty() {
                out.push((v.0, v.1[..v.1.len() / 2].to_vec()));
            }
            if v.0 > 1 {
                out.push((v.0 - 1, v.1.clone()));
            }
            out
        }
    }

    const CLASSES: [usize; 3] = [8, 64, 256];
    prop_check(150, 37, GenTraffic, |(cap, ops)| {
        let pool = TensorPool::new(*cap);
        let mut held = Vec::new();
        for &op in ops {
            if op < CLASSES.len() {
                held.push(pool.lease(CLASSES[op]));
            } else if !held.is_empty() {
                held.remove(held.len() / 2);
            }
        }
        drop(held);
        let s = pool.stats();
        let bound = cap * CLASSES.len();
        if s.buffers > bound {
            return Err(format!("{} shelved > bound {bound}", s.buffers));
        }
        if s.returned + s.dropped != s.hits + s.misses {
            return Err("every lease must be returned or dropped".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Concurrency
// ---------------------------------------------------------------------------

#[test]
fn concurrent_lease_return_is_safe_and_bounded() {
    let pool = TensorPool::new(4);
    let classes = [128usize, 512, 2048];
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for i in 0..300 {
                    let n = classes[rng.below(classes.len())];
                    let mut l = pool.lease(n);
                    // Touch the buffer like a real decode would.
                    l[0] = i as f32;
                    l[n - 1] = t as f32;
                    assert_eq!(l.len(), n);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let s = pool.stats();
    assert_eq!(s.hits + s.misses, 1200);
    assert!(
        s.buffers <= 4 * classes.len(),
        "shelved {} buffers above bound",
        s.buffers
    );
    assert_eq!(s.returned + s.dropped, 1200);
}

// ---------------------------------------------------------------------------
// Zero-copy views == owned unstack
// ---------------------------------------------------------------------------

#[test]
fn view_rows_equal_owned_unstack() {
    prop_check(
        100,
        41,
        GenPair(GenUsize { lo: 1, hi: 6 }, GenUsize { lo: 1, hi: 40 }),
        |(rows, per)| {
            let t = Tensor::random(&[*rows, *per], (*rows * 1000 + *per) as u64);
            let owned = t.unstack().map_err(|e| e.to_string())?;
            let v = t.view();
            if v.num_rows() != *rows {
                return Err("num_rows mismatch".into());
            }
            for (i, o) in owned.iter().enumerate() {
                let row = v.row(i);
                if row.shape() != o.shape() || row.data() != o.data() {
                    return Err(format!("row {i} differs from owned unstack"));
                }
                if row.argmax() != o.argmax() || row.topk(5) != o.topk(5) {
                    return Err(format!("row {i} reductions differ"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pooled_batch_assembly_matches_tensor_stack() {
    // The worker's in-place batching (rows copied into a leased batch
    // buffer) must produce exactly the bytes Tensor::stack used to.
    let pool = TensorPool::new(4);
    let imgs: Vec<Tensor> = (0..3).map(|i| Tensor::random(&[4, 5], i)).collect();
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let stacked = Tensor::stack(&refs).unwrap();

    let per = imgs[0].len();
    let mut bbuf = pool.lease(3 * per);
    for (slot, img) in imgs.iter().enumerate() {
        bbuf[slot * per..(slot + 1) * per].copy_from_slice(img.data());
    }
    assert_eq!(&bbuf[..], stacked.data());

    let bshape = [3usize, 4, 5];
    let v = view::TensorView::new(&bshape, &bbuf);
    for i in 0..3 {
        assert_eq!(v.row(i).data(), imgs[i].data());
    }
}

#[test]
fn topk_reference_equivalence_with_nans() {
    struct GenScores;
    impl Gen for GenScores {
        type Value = (Vec<usize>, usize); // (value codes, k)
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let n = rng.range(0, 50);
            // Small code space forces ties; code 7 becomes NaN.
            ((0..n).map(|_| rng.below(8)).collect(), rng.range(0, 12))
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out = Vec::new();
            if !v.0.is_empty() {
                out.push((v.0[..v.0.len() / 2].to_vec(), v.1));
            }
            if v.1 > 0 {
                out.push((v.0.clone(), v.1 - 1));
            }
            out
        }
    }

    prop_check(300, 43, GenScores, |(codes, k)| {
        let data: Vec<f32> = codes
            .iter()
            .map(|&c| if c == 7 { f32::NAN } else { c as f32 })
            .collect();
        let got = view::topk(&data, *k);
        // Reference: total order (value desc, NaN last, index asc).
        let mut want: Vec<(usize, f32)> = data.iter().copied().enumerate().collect();
        want.sort_by(|&(ai, av), &(bi, bv)| {
            let an = av.is_nan();
            let bn = bv.is_nan();
            match (an, bn) {
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (true, true) => ai.cmp(&bi),
                (false, false) => {
                    bv.partial_cmp(&av).unwrap().then(ai.cmp(&bi))
                }
            }
        });
        want.truncate(*k);
        // NaN != NaN, so compare via bits.
        if got.len() != want.len() {
            return Err(format!("len {} vs {}", got.len(), want.len()));
        }
        for (g, w) in got.iter().zip(&want) {
            if g.0 != w.0 || g.1.to_bits() != w.1.to_bits() {
                return Err(format!("got {got:?} want {want:?}"));
            }
        }
        // And argmax agrees with topk(1) when there is any entry.
        if !data.is_empty() {
            let top1 = view::topk(&data, 1)[0].0;
            if view::argmax(&data) != top1 {
                return Err("argmax disagrees with topk(1)".into());
            }
        }
        Ok(())
    });
}
