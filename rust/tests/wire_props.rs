//! Differential property test for the wire plane (ISSUE 8): the tape
//! scanner and the legacy tree parser (`--wire-parser tape|tree`) must
//! agree on **every** input — same accept/reject decision, identical
//! parsed message on accept, identical error text on reject, and the
//! same pre-decode wire key for keyable infer requests.
//!
//! Inputs come from a curated corpus plus a generator over the full
//! request grammar with adversarial mutations: truncation at arbitrary
//! bytes, byte flips and insertions (including invalid UTF-8), escape
//! and surrogate-pair injection, NaN-adjacent and precision-edge
//! numbers, duplicate keys, escaped key spellings, unknown fields, and
//! nesting on both sides of the depth bound.
//!
//! Case count is `WIRE_PROPS_CASES` (default 2000); CI runs the same
//! test with a much larger count.

use zuluko::config::WireParser;
use zuluko::server::protocol::{self, ClientMsg};
use zuluko::testkit::rng::Rng;
use zuluko::util::wire::WireTape;

/// Number spellings that stress the span fast path, f64 precision
/// edges, and the reject grammar.
const NUMS: &[&str] = &[
    "0",
    "-0",
    "1",
    "7",
    "42",
    "042",
    "4.2e1",
    "250",
    "2500",
    "1e308",
    "1e309",
    "-1e309",
    "1e-400",
    "5e-324",
    "9007199254740992",
    "9007199254740993",
    "18446744073709551615",
    "99999999999999999999",
    "1.",
    "01",
    ".5",
    "-",
    "1e",
    "1e+",
    "0x10",
    "NaN",
    "Infinity",
    "-1.5e-3",
    "1.7976931348623157e308",
];

/// String payloads (emitted verbatim between quotes): plain text,
/// well-formed escapes, surrogate pairs, lone surrogates, malformed
/// escapes, and raw multi-byte UTF-8.
const STRS: &[&str] = &[
    "squeezenet",
    "hi",
    "lo",
    "normal",
    "bogus",
    "",
    "a b",
    "\\n",
    "\\t",
    "\\\"",
    "\\\\",
    "\\/",
    "\\u0041",
    "\\u00e9",
    "\\ud83d\\ude00",
    "\\ud800",
    "\\udc00tail",
    "\\uD83D\\u0041",
    "\\uZZZZ",
    "\\q",
    "\\u12",
    "caf\u{e9}",
    "\u{65e5}\u{672c}",
];

const KEYS: &[&str] = &[
    "id",
    "cmd",
    "image",
    "synthetic",
    "ppm",
    "deadline_ms",
    "priority",
    "model",
    "n",
    "extra",
    "i\\u0064",
    "",
    "\u{6a21}",
];

/// The property: both parsers must agree in every observable way.
fn check(bytes: &[u8], tape: &mut WireTape) {
    let shown = String::from_utf8_lossy(bytes).into_owned();
    let tree = protocol::parse_request(&String::from_utf8_lossy(bytes));
    let taped = ClientMsg::parse_tape(bytes, tape);
    match (tree, taped) {
        (Ok(t), Ok(p)) => {
            assert_eq!(t, p, "parsed values diverged on {shown:?}");
            let (msg, key) = protocol::parse_line(WireParser::Tape, bytes, tape)
                .unwrap_or_else(|e| {
                    panic!("keyed tape parse rejected accepted input {shown:?}: {e}")
                });
            assert_eq!(msg, t, "keyed tape parse diverged on {shown:?}");
            match &t {
                ClientMsg::Infer { image, .. } => assert_eq!(
                    key,
                    protocol::wire_key(image),
                    "wire key diverged on {shown:?}"
                ),
                _ => assert_eq!(key, None, "non-infer message got a wire key on {shown:?}"),
            }
        }
        (Err(t), Err(p)) => {
            assert_eq!(
                t.to_string(),
                p.to_string(),
                "error text diverged on {shown:?}"
            );
        }
        (Ok(t), Err(p)) => panic!("tree accepts {shown:?} as {t:?}; tape rejects: {p}"),
        (Err(t), Ok(p)) => panic!("tape accepts {shown:?} as {p:?}; tree rejects: {t}"),
    }
}

fn push_field(out: &mut String, first: &mut bool, key: &str) {
    if !*first {
        out.push(',');
    }
    *first = false;
    out.push('"');
    out.push_str(key);
    out.push_str("\":");
}

/// Arbitrary JSON value over the token pools, depth-bounded.
fn gen_value(r: &mut Rng, depth: usize, out: &mut String) {
    let top = if depth >= 3 { 4 } else { 6 };
    match r.below(top) {
        0 | 3 => out.push_str(NUMS[r.below(NUMS.len())]),
        1 => {
            out.push('"');
            out.push_str(STRS[r.below(STRS.len())]);
            out.push('"');
        }
        2 => out.push_str(["true", "false", "null"][r.below(3)]),
        4 => {
            out.push('[');
            let n = r.below(3);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                gen_value(r, depth + 1, out);
            }
            out.push(']');
        }
        _ => {
            out.push('{');
            let n = r.below(3);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(KEYS[r.below(KEYS.len())]);
                out.push_str("\":");
                gen_value(r, depth + 1, out);
            }
            out.push('}');
        }
    }
}

/// Request-shaped document: mostly-valid field combinations with a
/// controlled dose of wrong types, unknown commands, and junk fields.
fn gen_request(r: &mut Rng) -> String {
    let mut out = String::from("{");
    let mut first = true;
    if r.chance(0.3) {
        push_field(&mut out, &mut first, "cmd");
        if r.chance(0.8) {
            out.push('"');
            out.push_str(
                [
                    "stats", "metrics", "trace", "policy", "models", "reload", "ping",
                    "hello", "bogus",
                ][r.below(9)],
            );
            out.push('"');
        } else {
            gen_value(r, 1, &mut out);
        }
        if r.chance(0.5) {
            push_field(&mut out, &mut first, "n");
            gen_value(r, 1, &mut out);
        }
        if r.chance(0.5) {
            push_field(&mut out, &mut first, "features");
            if r.chance(0.7) {
                out.push_str("{\"binary_frames\":");
                out.push_str(["true", "false", "1", "\"yes\"", "null"][r.below(5)]);
                out.push('}');
            } else {
                gen_value(r, 1, &mut out);
            }
        }
    }
    if r.chance(0.9) {
        push_field(&mut out, &mut first, "id");
        if r.chance(0.8) {
            out.push_str(NUMS[r.below(NUMS.len())]);
        } else {
            gen_value(r, 1, &mut out);
        }
    }
    if r.chance(0.9) {
        push_field(&mut out, &mut first, "image");
        if r.chance(0.6) {
            out.push_str("{\"synthetic\":");
            out.push_str(NUMS[r.below(NUMS.len())]);
            out.push('}');
        } else if r.chance(0.4) {
            out.push_str("{\"ppm\":\"");
            out.push_str(STRS[r.below(STRS.len())]);
            out.push_str("\"}");
        } else if r.chance(0.6) {
            // Frame headers: mostly-valid dims with number-grammar edge
            // cases in every slot, plus wrong-typed/missing members.
            out.push_str("{\"frame\":{");
            let mut ffirst = true;
            for key in ["len", "h", "w", "c"] {
                if r.chance(0.9) {
                    push_field(&mut out, &mut ffirst, key);
                    if r.chance(0.8) {
                        out.push_str(NUMS[r.below(NUMS.len())]);
                    } else {
                        gen_value(r, 2, &mut out);
                    }
                }
            }
            if r.chance(0.6) {
                push_field(&mut out, &mut ffirst, "dtype");
                if r.chance(0.7) {
                    out.push_str(["\"u8\"", "\"f32\"", "\"U8\"", "7"][r.below(4)]);
                } else {
                    gen_value(r, 2, &mut out);
                }
            }
            out.push_str("}}");
        } else {
            gen_value(r, 1, &mut out);
        }
    }
    if r.chance(0.4) {
        push_field(&mut out, &mut first, "deadline_ms");
        if r.chance(0.7) {
            out.push_str(NUMS[r.below(NUMS.len())]);
        } else {
            gen_value(r, 1, &mut out);
        }
    }
    if r.chance(0.4) {
        push_field(&mut out, &mut first, "priority");
        if r.chance(0.7) {
            out.push('"');
            out.push_str(["hi", "high", "normal", "mid", "lo", "low", "HI", "bogus"][r.below(8)]);
            out.push('"');
        } else {
            gen_value(r, 1, &mut out);
        }
    }
    if r.chance(0.4) {
        push_field(&mut out, &mut first, "model");
        if r.chance(0.7) {
            out.push('"');
            out.push_str(STRS[r.below(STRS.len())]);
            out.push('"');
        } else {
            gen_value(r, 1, &mut out);
        }
    }
    if r.chance(0.2) {
        push_field(&mut out, &mut first, KEYS[r.below(KEYS.len())]);
        gen_value(r, 1, &mut out);
    }
    out.push('}');
    out
}

/// Structural mutations: truncate, flip, insert (any byte value, so
/// invalid UTF-8 lands both inside strings and between tokens), delete,
/// and whitespace injection.
fn mutate(r: &mut Rng, bytes: &mut Vec<u8>) {
    match r.below(5) {
        0 => {
            if !bytes.is_empty() {
                let at = r.below(bytes.len());
                bytes.truncate(at);
            }
        }
        1 => {
            if !bytes.is_empty() {
                let at = r.below(bytes.len());
                bytes[at] = (r.next_u64() & 0xff) as u8;
            }
        }
        2 => {
            let at = r.below(bytes.len() + 1);
            bytes.insert(at, (r.next_u64() & 0xff) as u8);
        }
        3 => {
            if !bytes.is_empty() {
                let at = r.below(bytes.len());
                bytes.remove(at);
            }
        }
        _ => {
            let at = r.below(bytes.len() + 1);
            for (i, b) in b" \t ".iter().enumerate() {
                bytes.insert(at + i, *b);
            }
        }
    }
}

/// Hand-picked inputs exercising every known grammar quirk; these run
/// on every invocation regardless of the case budget.
fn curated() -> Vec<Vec<u8>> {
    let mut v: Vec<Vec<u8>> = [
        r#"{"id":1,"image":{"synthetic":42}}"#,
        r#"{"id":1,"image":{"synthetic":4.2e1},"deadline_ms":250,"priority":"hi"}"#,
        r#"{"id":1,"image":{"synthetic":042}}"#,
        r#"{"id":1,"image":{"ppm":"/tmp/x.ppm"},"model":"squeezenet"}"#,
        r#"{"id":1,"image":{"synthetic":1}}"#,
        r#"{"id":1,"id":2,"image":{"synthetic":1},"image":{"synthetic":3}}"#,
        r#"{"cmd":"trace","n":0}"#,
        r#"{"cmd":"trace","n":1e9}"#,
        r#"{"cmd":7}"#,
        r#"{"id":1,"image":{"synthetic":-5}}"#,
        r#"{"id":1,"image":{"synthetic":1e309}}"#,
        r#"{"id":1,"image":{"synthetic":"9"}}"#,
        r#"{"id":1,"image":{"synthetic":9007199254740993}}"#,
        r#"{"id":1,"image":{"synthetic":18446744073709551615}}"#,
        r#"{"id":1.5,"image":{"synthetic":1}}"#,
        r#"{"id":1,"image":{"synthetic":1},"model":"😀"}"#,
        r#"{"id":1,"image":{"synthetic":1},"model":"\ud800"}"#,
        r#"{"id":1,"image":{"synthetic":1},"priority":"HI"}"#,
        r#"{"id":1,"image":{"synthetic":1}} "#,
        r#"  {"id":1,"image":{"synthetic":1}}"#,
        r#"{"id":1,"image":{"synthetic":1}}x"#,
        r#"{"id":1,"image":{"synthetic":1}"#,
        r#"{"id":1,"#,
        "",
        " \t ",
        "null",
        "[]",
        "{}",
        "42",
        "\"x\"",
        r#"{"cmd":"ping"}"#,
        r#"{"cmd":"reload","model":"resnet"}"#,
        r#"{"cmd":"reload","model":7}"#,
        r#"{"cmd":"hello"}"#,
        r#"{"cmd":"hello","features":{"binary_frames":true}}"#,
        r#"{"cmd":"hello","features":{"binary_frames":false}}"#,
        r#"{"cmd":"hello","features":{"binary_frames":1}}"#,
        r#"{"cmd":"hello","features":{}}"#,
        r#"{"cmd":"hello","features":null}"#,
        r#"{"cmd":"hello","features":["binary_frames"]}"#,
        r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":"u8"}}}"#,
        r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3}}}"#,
        r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2}}}"#,
        r#"{"id":1,"image":{"frame":{"len":-1,"h":2,"w":2,"c":3}}}"#,
        r#"{"id":1,"image":{"frame":{"len":1.5,"h":2,"w":2,"c":3}}}"#,
        r#"{"id":1,"image":{"frame":{"len":12,"h":2,"w":2,"c":3,"dtype":7}}}"#,
        r#"{"id":1,"image":{"frame":7}}"#,
        r#"{"id":1,"image":{"frame":{}}}"#,
        r#"{"id":1,"image":{"synthetic":1,"frame":{"len":3,"h":1,"w":1,"c":3}}}"#,
        r#"{"id":1,"image":{"frame":{"len":18446744073709551615,"h":2,"w":2,"c":3}}}"#,
    ]
    .iter()
    .map(|s| s.as_bytes().to_vec())
    .collect();
    // Invalid UTF-8 inside a string value, and loose between tokens.
    v.push(b"{\"id\":1,\"image\":{\"synthetic\":1},\"model\":\"a\xffb\"}".to_vec());
    v.push(b"{\"id\":1,\xff\"image\":{\"synthetic\":1}}".to_vec());
    // Nesting past the depth bound (truncated, so also unterminated).
    let mut deep = String::from("{\"id\":1,\"image\":");
    deep.push_str(&"[".repeat(200));
    v.push(deep.into_bytes());
    // Deep but within bounds, balanced, on an ignored field.
    let mut ok_deep = String::from("{\"id\":1,\"image\":{\"synthetic\":1},\"x\":");
    ok_deep.push_str(&"[".repeat(40));
    ok_deep.push_str(&"]".repeat(40));
    ok_deep.push('}');
    v.push(ok_deep.into_bytes());
    v
}

#[test]
fn tape_and_tree_agree_on_generated_corpus() {
    let cases: usize = std::env::var("WIRE_PROPS_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let mut tape = WireTape::new();
    for c in curated() {
        check(&c, &mut tape);
    }
    let mut r = Rng::new(0xA11CE);
    for _ in 0..cases {
        let mut bytes = if r.chance(0.7) {
            gen_request(&mut r).into_bytes()
        } else {
            let mut s = String::new();
            gen_value(&mut r, 0, &mut s);
            s.into_bytes()
        };
        for _ in 0..r.below(3) {
            mutate(&mut r, &mut bytes);
        }
        check(&bytes, &mut tape);
    }
}
