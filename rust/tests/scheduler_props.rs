//! Shared-runtime invariants (DESIGN.md §4), end-to-end over the sim
//! engine — no artifacts or XLA needed, so these run everywhere
//! including CI:
//!
//! * the worker-thread count equals the configured runtime size — it
//!   does not scale with model count, and hot reloads do not spawn a
//!   second thread army;
//! * a saturating hot model cannot starve a cold model's deadlined
//!   requests (EDF override + weighted fair share): the cold model's
//!   requests all complete inside their deadlines with bounded p99;
//! * every admitted request still gets exactly one response under the
//!   shared runtime, across models and mixed SLOs;
//! * the replica-cache byte bound is hard under eviction: after any
//!   operation sequence, retained bytes never exceed
//!   max(budget, the single entry just inserted).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use zuluko::config::Config;
use zuluko::coordinator::scheduler::{QueueKey, ReplicaCache};
use zuluko::coordinator::{Coordinator, SubmitError};
use zuluko::engine::EngineKind;
use zuluko::policy::Slo;
use zuluko::tensor::Tensor;
use zuluko::testkit::prop::{prop_check, Gen};
use zuluko::testkit::rng::Rng;
use zuluko::testkit::sched::threads_named;
use zuluko::util::percentile_sorted;

/// Small input so test tensors are cheap (the sim engine takes any hw).
const HW: usize = 32;
const CLASSES: usize = 100;

fn model_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zuluko_sched_props_{tag}_{}",
        std::process::id()
    ));
    zuluko::testkit::manifest::write_synthetic(&dir, tag, CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

fn multi_model_cfg(models: &[&str], workers: usize) -> Config {
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers,
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 16,
        ..Config::default()
    };
    for m in models {
        cfg.registry.upsert(m, model_dir(m));
    }
    cfg.registry.default_model = Some(models[0].to_string());
    cfg.registry.preload = true;
    cfg.validate().unwrap();
    cfg
}

fn frame(seed: u64) -> Tensor {
    Tensor::random(&[HW, HW, 3], seed)
}

/// Tests that spawn coordinators run serially so thread accounting (and
/// CPU-sensitive latency bounds) never see a sibling test's fleet.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// "zuluko-runtime-N" truncated at the kernel's 15-char comm limit.
const RUNTIME_PREFIX: &str = "zuluko-runtime";
/// Any thread this crate spawns (runtime workers, retire waiters, ...).
const ANY_PREFIX: &str = "zuluko-";

/// Wait until the `prefix`-named thread count settles to `want`
/// (transient retire waiters exit asynchronously after a drain).
fn settles_to(prefix: &str, want: usize, within: Duration) -> bool {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < within {
        if threads_named(prefix) == want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    threads_named(prefix) == want
}

// ---------------------------------------------------------------------------
// Acceptance: fixed thread budget, regardless of model count / reloads.
// ---------------------------------------------------------------------------

#[test]
fn thread_count_equals_runtime_size_across_models_and_reloads() {
    let _serial = serial();
    const RUNTIME: usize = 2;
    assert!(
        settles_to(ANY_PREFIX, 0, Duration::from_secs(5)),
        "a previous test leaked zuluko threads"
    );
    let coord = Coordinator::start(&multi_model_cfg(&["ta", "tb", "tc"], RUNTIME)).unwrap();

    // Three preloaded models, yet exactly RUNTIME worker threads — not
    // 2 × models × workers.
    assert_eq!(
        threads_named(RUNTIME_PREFIX),
        RUNTIME,
        "worker threads must not scale with model count"
    );

    // Serve something on every model so replicas exist, then reload
    // every model: the drain must not spawn a second thread army (one
    // transient retire waiter per reload is allowed, but it exits).
    for m in ["ta", "tb", "tc"] {
        let r = coord
            .submit_model(Some(m), frame(7), Slo::default())
            .unwrap()
            .recv()
            .unwrap();
        assert!(r.is_ok(), "{m}: {:?}", r.error);
    }
    for m in ["ta", "tb", "tc"] {
        coord.reload(Some(m)).unwrap();
    }
    // The worker fleet never grew, and the transient retire waiters
    // (the only extra threads a reload may briefly hold) exit with the
    // drain — no second thread army.
    assert_eq!(threads_named(RUNTIME_PREFIX), RUNTIME);
    assert!(
        settles_to(ANY_PREFIX, RUNTIME, Duration::from_secs(5)),
        "threads did not settle back to the runtime size after reloads: \
         {} zuluko threads (want {RUNTIME})",
        threads_named(ANY_PREFIX)
    );

    // Old generations drained: every model still answers, on gen 2.
    for m in ["ta", "tb", "tc"] {
        let r = coord
            .submit_model(Some(m), frame(8), Slo::default())
            .unwrap()
            .recv()
            .unwrap();
        assert!(r.is_ok(), "{m} died after reload: {:?}", r.error);
    }
    let stats = coord.stats();
    for row in &stats.models {
        assert_eq!(row.generation, 2, "{}", row.model);
    }
    // Scheduler health is visible: occupancy rows match the fleet, and
    // only live generations' queues remain.
    assert_eq!(stats.workers.len(), RUNTIME);
    assert!(stats.queues.iter().all(|q| q.generation == 2));

    coord.shutdown();
    assert!(
        settles_to(ANY_PREFIX, 0, Duration::from_secs(5)),
        "shutdown leaked threads: {} zuluko threads remain",
        threads_named(ANY_PREFIX)
    );
}

// ---------------------------------------------------------------------------
// Acceptance: a saturating hot model cannot starve a cold model.
// ---------------------------------------------------------------------------

#[test]
fn hot_model_cannot_starve_cold_deadlines() {
    let _serial = serial();
    let coord = Arc::new(Coordinator::start(&multi_model_cfg(&["hot", "cold"], 2)).unwrap());

    // Saturate the hot model from two producers (best-effort requests,
    // replies dropped — only pressure matters).
    let stop = Arc::new(AtomicBool::new(false));
    let producers: Vec<_> = (0..2)
        .map(|p| {
            let coord = coord.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let img = frame(1000 + p);
                let mut sent = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match coord.submit_model(Some("hot"), img.clone(), Slo::default()) {
                        Ok(rx) => {
                            drop(rx);
                            sent += 1;
                        }
                        Err(SubmitError::Overloaded) => {
                            std::thread::yield_now();
                        }
                        Err(e) => panic!("hot submit: {e}"),
                    }
                }
                sent
            })
        })
        .collect();

    // Give the producers a head start so the hot queue is saturated.
    std::thread::sleep(Duration::from_millis(50));

    // Cold model: sequential deadlined requests.  Under the shared
    // runtime every one must complete inside its (generous) deadline —
    // the starvation failure mode is a timeout/shed here.
    const COLD_REQS: usize = 40;
    const DEADLINE_MS: f64 = 500.0;
    let mut latencies = Vec::with_capacity(COLD_REQS);
    for i in 0..COLD_REQS {
        let rx = coord
            .submit_model(
                Some("cold"),
                frame(2000 + i as u64),
                Slo::with_deadline_ms(DEADLINE_MS),
            )
            .expect("cold submit must admit (its queue is its own)");
        let r = rx.recv().expect("cold request dropped");
        assert!(
            r.is_ok(),
            "cold request {i} starved under hot load: {:?} ({})",
            r.error,
            r.kind
        );
        latencies.push(r.total_ms);
    }
    stop.store(true, Ordering::Relaxed);
    let hot_sent: u64 = producers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(hot_sent > 0, "hot producers sent nothing — test proved nothing");

    latencies.sort_by(f64::total_cmp);
    let p99 = percentile_sorted(&latencies, 99.0);
    assert!(
        p99 < DEADLINE_MS,
        "cold p99 {p99:.1}ms not bounded under hot saturation"
    );

    let coord = Arc::try_unwrap(coord).ok().expect("producers joined");
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Property: exactly one response per admitted request, mixed SLOs.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct MixCase {
    requests: usize,
    seed: u64,
}

struct GenMixCase;

impl Gen for GenMixCase {
    type Value = MixCase;
    fn generate(&self, rng: &mut Rng) -> MixCase {
        MixCase {
            requests: rng.range(4, 24),
            seed: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &MixCase) -> Vec<MixCase> {
        if v.requests > 4 {
            vec![MixCase {
                requests: v.requests / 2,
                ..v.clone()
            }]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn prop_exactly_one_response_per_admitted_request() {
    let _serial = serial();
    let coord = Coordinator::start(&multi_model_cfg(&["pa", "pb"], 2)).unwrap();
    prop_check(8, 37, GenMixCase, |case| {
        let mut receivers = Vec::new();
        let mut rng = Rng::new(case.seed | 1);
        for i in 0..case.requests {
            let model = if i % 2 == 0 { "pa" } else { "pb" };
            let slo = match rng.range(0, 3) {
                0 => Slo::default(),
                1 => Slo::with_deadline_ms(500.0),
                // Tight but feasible for the sim engine; may shed at
                // admission (Err — not admitted) or expire in queue
                // (one structured response) — both legal.
                _ => Slo::with_deadline_ms(2.0),
            };
            match coord.submit_model(Some(model), frame(rng.next_u64()), slo) {
                Ok(rx) => receivers.push((i, rx)),
                Err(SubmitError::Shed { .. } | SubmitError::Overloaded) => {}
                Err(e) => return Err(format!("submit {i}: {e}")),
            }
        }
        for (i, rx) in receivers {
            // Exactly one response...
            let first = rx
                .recv_timeout(Duration::from_secs(10))
                .map_err(|_| format!("request {i} got no response"))?;
            if first.kind == "shed" && first.error.is_none() {
                return Err(format!("request {i}: shed without error text"));
            }
            // ...and never a second (the worker drops its sender after
            // the reply; a duplicate would sit in the channel).
            std::thread::sleep(Duration::from_millis(1));
            if rx.try_recv().is_ok() {
                return Err(format!("request {i} got two responses"));
            }
        }
        Ok(())
    });
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Property: the replica-cache byte bound is hard under eviction.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum CacheOp {
    Insert { key: u8, bytes: usize },
    Get { key: u8 },
}

#[derive(Debug, Clone)]
struct CacheCase {
    budget: usize,
    ops: Vec<CacheOp>,
}

struct GenCacheCase;

impl Gen for GenCacheCase {
    type Value = CacheCase;
    fn generate(&self, rng: &mut Rng) -> CacheCase {
        let budget = rng.range(50, 400);
        let n = rng.range(1, 60);
        let ops = (0..n)
            .map(|_| {
                if rng.range(0, 4) == 0 {
                    CacheOp::Get {
                        key: rng.range(0, 6) as u8,
                    }
                } else {
                    CacheOp::Insert {
                        key: rng.range(0, 6) as u8,
                        bytes: rng.range(1, 500),
                    }
                }
            })
            .collect();
        CacheCase { budget, ops }
    }
    fn shrink(&self, v: &CacheCase) -> Vec<CacheCase> {
        if v.ops.len() > 1 {
            vec![CacheCase {
                budget: v.budget,
                ops: v.ops[..v.ops.len() / 2].to_vec(),
            }]
        } else {
            Vec::new()
        }
    }
}

fn qkey(k: u8) -> QueueKey {
    QueueKey {
        model: Arc::from(format!("m{k}").as_str()),
        generation: 1,
        engine: EngineKind::Sim,
    }
}

#[test]
fn prop_replica_cache_byte_bound_is_hard() {
    prop_check(300, 43, GenCacheCase, |case| {
        let mut cache: ReplicaCache<u64> = ReplicaCache::new(case.budget);
        for (step, op) in case.ops.iter().enumerate() {
            match op {
                CacheOp::Insert { key, bytes } => {
                    cache.insert(qkey(*key), step as u64, *bytes);
                    let limit = case.budget.max(*bytes);
                    if cache.total_bytes() > limit {
                        return Err(format!(
                            "step {step}: {} bytes retained, bound {limit} \
                             (budget {}, inserted {bytes})",
                            cache.total_bytes(),
                            case.budget
                        ));
                    }
                    // An over-budget single entry must be alone.
                    if *bytes > case.budget && cache.len() != 1 {
                        return Err(format!(
                            "step {step}: oversized entry kept company \
                             (len {})",
                            cache.len()
                        ));
                    }
                }
                CacheOp::Get { key } => {
                    let _ = cache.get(&qkey(*key));
                }
            }
        }
        Ok(())
    });
}
