//! Property tests on coordinator invariants (DESIGN.md §6), using the
//! in-tree testkit::prop framework.  These run against the queue/batcher/
//! router primitives with plain payloads (no XLA needed — fast), plus one
//! end-to-end packing-independence test against the real engine when
//! artifacts exist.

use std::sync::Arc;
use std::time::Duration;

use zuluko::coordinator::batcher::BatchPolicy;
use zuluko::coordinator::queue::BoundedQueue;
use zuluko::coordinator::router::{EnginePort, RouteError};
use zuluko::coordinator::scheduler::Scheduler;
use zuluko::coordinator::Request;
use zuluko::testkit::prop::{prop_check, Gen, GenPair, GenUsize, GenVecUsize};
use zuluko::testkit::rng::Rng;
use zuluko::testkit::sched::{dummy_request, sim_source};

// ---------------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------------

struct GenPolicyAndLoad;

impl Gen for GenPolicyAndLoad {
    type Value = (usize, Vec<usize>); // (max_batch, queued item ids)
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let max_batch = rng.range(1, 12);
        let n = rng.range(0, 30);
        (max_batch, (0..n).collect())
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.0 > 1 {
            out.push((v.0 - 1, v.1.clone()));
        }
        if !v.1.is_empty() {
            out.push((v.0, v.1[..v.1.len() / 2].to_vec()));
        }
        out
    }
}

#[test]
fn prop_batch_size_always_supported_and_bounded() {
    let supported = [1usize, 2, 4, 8];
    prop_check(300, 11, GenPolicyAndLoad, |(max_batch, items)| {
        let policy = BatchPolicy::new(*max_batch, Duration::ZERO, &supported);
        let q = BoundedQueue::new(64);
        for &i in items {
            q.try_push(i).map_err(|_| "push failed".to_string())?;
        }
        if items.is_empty() {
            return Ok(()); // form() would block; nothing to check
        }
        let batch = policy.form(&q).ok_or("no batch from non-empty queue")?;
        if batch.is_empty() {
            return Err("empty batch".into());
        }
        if batch.len() > *max_batch {
            return Err(format!("batch {} > max {}", batch.len(), max_batch));
        }
        if !supported.contains(&batch.len()) {
            return Err(format!("unsupported batch size {}", batch.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_preserved_across_batches() {
    prop_check(200, 13, GenUsize { lo: 1, hi: 40 }, |&n| {
        let policy = BatchPolicy::new(8, Duration::ZERO, &[1, 2, 4, 8]);
        let q = BoundedQueue::new(64);
        for i in 0..n {
            q.try_push(i).map_err(|_| "push".to_string())?;
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            let batch = policy.form(&q).ok_or("closed")?;
            seen.extend(batch);
        }
        let expect: Vec<usize> = (0..n).collect();
        if seen != expect {
            return Err(format!("order violated: {seen:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_no_request_lost_or_duplicated() {
    prop_check(
        200,
        17,
        GenPair(
            GenUsize { lo: 1, hi: 10 },
            GenVecUsize {
                len_lo: 0,
                len_hi: 50,
                lo: 0,
                hi: 1_000_000,
            },
        ),
        |(max_batch, payloads)| {
            let policy = BatchPolicy::new(*max_batch, Duration::ZERO, &[1, 2, 4, 8]);
            let q = BoundedQueue::new(128);
            for &p in payloads {
                q.try_push(p).map_err(|_| "push".to_string())?;
            }
            let mut out = Vec::new();
            while !q.is_empty() {
                out.extend(policy.form(&q).ok_or("closed")?);
            }
            if out != *payloads {
                return Err("lost/duplicated/reordered items".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Queue invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_queue_capacity_is_hard_bound() {
    prop_check(
        200,
        19,
        GenPair(GenUsize { lo: 1, hi: 16 }, GenUsize { lo: 0, hi: 64 }),
        |(cap, pushes)| {
            let q = BoundedQueue::new(*cap);
            let mut accepted = 0;
            for i in 0..*pushes {
                if q.try_push(i).is_ok() {
                    accepted += 1;
                }
            }
            if accepted != (*pushes).min(*cap) {
                return Err(format!(
                    "accepted {accepted}, expected {}",
                    (*pushes).min(*cap)
                ));
            }
            if q.len() > *cap {
                return Err("len exceeds capacity".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Admission-port invariants (the shared runtime's submit surface)
// ---------------------------------------------------------------------------

/// An admission port over a fresh (model, engine) queue of `cap` slots
/// (fixtures shared via testkit::sched — one constructor to evolve).
fn test_port(tag: &str, cap: usize) -> EnginePort {
    let source = sim_source(tag, 1.0, cap);
    let scheduler = Arc::new(Scheduler::new(Duration::from_millis(50)));
    scheduler.register(source.clone());
    EnginePort::new(source, scheduler)
}

fn test_request(id: u64) -> Request {
    dummy_request(id, None)
}

#[test]
fn prop_admission_never_drops_silently() {
    // Every submitted request is either admitted to the queue or comes
    // back to the caller inside the error; total conservation holds and
    // rejection only happens at true capacity.
    prop_check(
        100,
        23,
        GenPair(GenUsize { lo: 1, hi: 8 }, GenUsize { lo: 0, hi: 24 }),
        |(cap, n)| {
            let port = test_port("conserve", *cap);
            let mut admitted = 0usize;
            let mut rejected = 0usize;
            for i in 0..*n {
                match port.admit(test_request(i as u64)) {
                    Ok(()) => admitted += 1,
                    Err(RouteError::Overloaded(r)) => {
                        if r.id != i as u64 {
                            return Err("wrong request bounced".into());
                        }
                        if port.queued() < port.capacity() {
                            return Err("rejected while capacity remained".into());
                        }
                        rejected += 1;
                    }
                    Err(RouteError::Closed(_)) => {
                        return Err("unexpected close".into())
                    }
                }
            }
            if admitted != port.queued() {
                return Err(format!(
                    "admitted {admitted} != queued {}",
                    port.queued()
                ));
            }
            if admitted + rejected != *n {
                return Err("conservation violated".into());
            }
            Ok(())
        },
    );
}

#[test]
fn closed_port_bounces_with_the_request() {
    let port = test_port("closed", 4);
    port.admit(test_request(1)).unwrap();
    port.close();
    match port.admit(test_request(2)) {
        Err(RouteError::Closed(r)) => assert_eq!(r.id, 2),
        other => panic!("expected Closed, got {other:?}"),
    }
    // Residual item survives the close (graceful drain).
    assert_eq!(port.queued(), 1);
}

// ---------------------------------------------------------------------------
// End-to-end: batch packing never changes results (needs artifacts)
// ---------------------------------------------------------------------------

#[test]
fn packing_independence_on_real_engine() {
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use zuluko::engine::{build, EngineKind};
    use zuluko::tensor::Tensor;

    let m = zuluko::runtime::Manifest::load(&dir).unwrap();
    let mut e = build(EngineKind::AclStaged, &m).unwrap();
    let imgs: Vec<Tensor> = (0..4).map(|i| Tensor::random(&[227, 227, 3], i)).collect();

    // One by one.
    let mut solo = Vec::new();
    for img in &imgs {
        let mut s = vec![1usize];
        s.extend(img.shape());
        let b = img.clone().reshape(&s).unwrap();
        solo.push(e.infer(&b).unwrap());
    }
    // Packed as a 4-batch.
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let packed = e.infer(&Tensor::stack(&refs).unwrap()).unwrap();
    for (i, row) in packed.unstack().unwrap().into_iter().enumerate() {
        let row = row.reshape(&[1, 1000]).unwrap();
        let (abs, _) = row.max_abs_rel_diff(&solo[i]).unwrap();
        assert!(abs < 1e-4, "packing changed result for image {i}: {abs}");
        assert_eq!(row.argmax(), solo[i].argmax());
    }
}
