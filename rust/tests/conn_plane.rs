//! Connection-plane invariants (DESIGN.md §"Connection plane"), over
//! the sim engine — no artifacts needed, so these run everywhere
//! including CI:
//!
//! * one connection can pipeline many requests and every one is
//!   answered exactly once with its own id and its own answer;
//! * a client that floods requests but never drains replies trips
//!   write backpressure (reads pause, its memory footprint is bounded)
//!   without starving other connections, and recovers once it drains;
//! * idle connections are evicted by the idle timeout;
//! * the connection cap answers a structured `at_capacity` line and
//!   the slot is reusable after a close;
//! * an oversize request line is a structured `bad_request` + close on
//!   both planes (the threads plane must hold the same contract — it
//!   is the E13 ablation baseline, not a second protocol);
//! * the event plane's thread count is independent of connection
//!   count (the whole point of the reactor);
//! * the binary frame lane (ISSUE 9): `{"cmd":"hello"}` negotiation,
//!   frames interleaved with JSON lines on one pipelined connection
//!   answered exactly once with lane-identical results, structured
//!   `bad_frame`/`unsupported_feature` rejects that leave the
//!   connection recoverable, and mid-frame disconnects that don't
//!   wedge the server — on both planes;
//! * every reject on either plane carries the unified error schema
//!   (`ok:false`, documented `kind`, human `msg`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zuluko::config::{Config, ConnPlane, ServerConfig, WireParser};
use zuluko::coordinator::Coordinator;
use zuluko::engine::sim::expected_top1;
use zuluko::engine::EngineKind;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::tensor::image::Image;
use zuluko::testkit::sched::threads_named;
use zuluko::util::json::Json;

const HW: usize = 64;
const CLASSES: usize = 100;
const MODEL: &str = "m";

/// A fresh synthetic-model artifacts dir, unique per test.
fn model_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("zuluko_conn_plane_{tag}_{}", std::process::id()));
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

/// One sim model behind a small shared runtime.
fn sim_cfg(tag: &str) -> Config {
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(5),
        queue_capacity: 64,
        ..Config::default()
    };
    cfg.registry.upsert(MODEL, model_dir(tag));
    cfg.registry.default_model = Some(MODEL.to_string());
    cfg.validate().unwrap();
    cfg
}

fn start(tag: &str, server: ServerConfig) -> (Server, Arc<Coordinator>) {
    let mut cfg = sim_cfg(tag);
    cfg.server = server;
    cfg.validate().unwrap();
    let coord = Arc::new(Coordinator::start(&cfg).unwrap());
    let s = Server::start_with(coord.clone(), "127.0.0.1:0", &cfg.server).unwrap();
    (s, coord)
}

/// Exactly the pixels the server decodes for `{"synthetic": seed}`.
fn frame_pixels(seed: u64) -> Vec<f32> {
    let img = Image::synthetic(HW, HW, seed);
    let mut buf = vec![0.0f32; HW * HW * 3];
    img.to_input_into(&mut buf);
    buf
}

/// Raw u8 RGB whose frame-lane decode equals `frame_pixels(seed)` —
/// what a client ships to get the same answer as `{"synthetic":seed}`.
fn frame_rgb(seed: u64) -> Vec<u8> {
    Image::synthetic(HW, HW, seed).rgb
}

fn frame_header_line(id: u64, len: usize, h: usize, w: usize, c: usize) -> String {
    format!(
        "{{\"id\":{id},\"image\":{{\"frame\":{{\"len\":{len},\"h\":{h},\"w\":{w},\"c\":{c},\"dtype\":\"u8\"}}}}}}\n"
    )
}

const HELLO_FRAMES: &[u8] = b"{\"cmd\":\"hello\",\"features\":{\"binary_frames\":true}}\n";

/// Tear down server + coordinator: wait for server threads to release
/// their Arc clones, then shutdown.
fn stop_all(server: Server, mut coord: Arc<Coordinator>) {
    server.stop();
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    coord.shutdown();
}

fn wait_until(timeout: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    ok()
}

#[test]
fn pipelined_requests_all_answered_exactly_once() {
    let (server, coord) = start("pipeline", ServerConfig::default());
    let addr = server.addr();

    const N: u64 = 32;
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    // Write every request before reading a single reply: this only
    // completes if the server keeps reading and answering out of a
    // completion queue instead of one blocking recv per request.
    let mut burst = String::new();
    for id in 0..N {
        burst.push_str(&format!(
            "{{\"id\":{id},\"image\":{{\"synthetic\":{}}}}}\n",
            1000 + id
        ));
    }
    w.write_all(burst.as_bytes()).unwrap();

    let mut seen = std::collections::HashSet::new();
    for _ in 0..N {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "early EOF");
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "got: {line}"
        );
        let id = j.usize_of("id").unwrap() as u64;
        // Each reply carries its own request's answer (sim's top1 is a
        // pure function of the pixels): replies never cross requests.
        assert_eq!(
            j.usize_of("top1").unwrap(),
            expected_top1(MODEL, &frame_pixels(1000 + id), CLASSES),
            "reply {id} carries another request's result"
        );
        assert!(seen.insert(id), "id {id} answered twice");
    }
    assert_eq!(seen.len(), N as usize);

    let snap = server.conn_snapshot();
    assert_eq!(snap.completions, N, "every request went through the sink");
    assert!(
        snap.peak_conn_in_flight >= 2,
        "burst of {N} never overlapped in flight (peak {})",
        snap.peak_conn_in_flight
    );

    // The stats line reports the connection plane.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let stats = c.stats().unwrap();
    let conn = stats.get("conn").expect("stats line has a conn section");
    assert_eq!(conn.get("plane").and_then(|v| v.as_str()), Some("event"));
    assert!(conn.usize_of("accepted").unwrap() >= 2);

    drop((reader, w, c));
    stop_all(server, coord);
}

#[test]
fn slow_reader_hits_backpressure_without_starving_others() {
    let (server, coord) = start("backpressure", ServerConfig::default());
    let addr = server.addr();

    // Flood stats requests (each reply is ~1 KB) and read nothing: the
    // replies must pile into this connection's write buffer until the
    // high watermark pauses its reads.  Sized so total reply bytes far
    // exceed what the kernel's socket buffers could silently absorb.
    const N: usize = 12_000;
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let burst = "{\"cmd\":\"stats\"}\n".repeat(N);
    w.write_all(burst.as_bytes()).unwrap();

    assert!(
        wait_until(Duration::from_secs(20), || {
            server.conn_snapshot().backpressure_events >= 1
        }),
        "flooded connection never tripped backpressure: {:?}",
        server.conn_snapshot()
    );

    // A second connection stays responsive while the first is parked.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    assert!(c.ping().unwrap());
    let r = c.infer(&InferRequest::new(1).synthetic(99)).unwrap();
    assert!(r.ok, "other connection starved: {:?}", r.error);

    // Drain the flood: every reply arrives (nothing was dropped under
    // pressure), and the connection reads again afterwards.
    for i in 0..N {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "reply {i}/{N} missing"
        );
        assert!(line.contains("\"ok\":true"), "reply {i}: {line}");
    }
    w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "reads never resumed after drain");

    drop((reader, w, c));
    stop_all(server, coord);
}

#[test]
fn idle_timeout_evicts_quiet_connections() {
    let (server, coord) = start(
        "idle",
        ServerConfig {
            idle_timeout_ms: 200,
            ..ServerConfig::default()
        },
    );

    let stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    // Prove the connection is live, then go quiet.
    w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"));

    // The server must close us: read returns EOF, not a timeout.
    line.clear();
    let n = reader.read_line(&mut line).expect("expected EOF, got error");
    assert_eq!(n, 0, "expected eviction, got: {line}");
    assert!(server.conn_snapshot().idle_evicted >= 1);
    assert!(
        wait_until(Duration::from_secs(5), || {
            server.conn_snapshot().connections == 0
        }),
        "evicted connection still counted"
    );

    drop((reader, w));
    stop_all(server, coord);
}

#[test]
fn connection_cap_is_a_structured_reject_and_slots_recycle() {
    let (server, coord) = start(
        "cap",
        ServerConfig {
            max_connections: 2,
            ..ServerConfig::default()
        },
    );
    let addr = server.addr().to_string();

    let mut c1 = Client::connect(&addr).unwrap();
    let mut c2 = Client::connect(&addr).unwrap();
    assert!(c1.ping().unwrap());
    assert!(c2.ping().unwrap());

    // Third connection: structured at_capacity line, then close — a
    // load generator can tell shed-at-socket from network failure.
    let over = TcpStream::connect(server.addr()).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(over);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(
        j.get("kind").and_then(|v| v.as_str()),
        Some("at_capacity"),
        "got: {line}"
    );
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "must close after reject");
    assert!(server.conn_snapshot().rejected_at_capacity >= 1);

    // Close one admitted connection; its slot must become reusable.
    drop(c1);
    assert!(
        wait_until(Duration::from_secs(5), || {
            server.conn_snapshot().connections <= 1
        }),
        "closed connection never released its slot"
    );
    let mut c3 = Client::connect(&addr).unwrap();
    assert!(c3.ping().unwrap(), "freed slot not reusable");

    drop((c2, c3, reader));
    stop_all(server, coord);
}

/// Oversize contract shared by both planes: structured `bad_request`
/// naming the limit, then close — never an unbounded buffer, never a
/// silent drop.
fn assert_oversize_contract(addr: &str, max_line_bytes: usize) {
    // A complete line over the limit.
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut big = vec![b'a'; max_line_bytes + 64];
    big.push(b'\n');
    w.write_all(&big).unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    assert!(line.contains("bad_request"), "got: {line}");
    assert!(line.contains("exceeds"), "got: {line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "must close");

    // A newline-less stream past the limit: the reject must fire
    // without waiting for a terminator that never comes.
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(&vec![b'b'; max_line_bytes + 1]).unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "no reject line");
    assert!(line.contains("bad_request"), "got: {line}");
}

#[test]
fn oversize_line_rejected_event_plane() {
    let max = 512;
    let (server, coord) = start(
        "oversize_event",
        ServerConfig {
            max_line_bytes: max,
            ..ServerConfig::default()
        },
    );
    assert_oversize_contract(&server.addr().to_string(), max);
    assert!(server.conn_snapshot().oversize_rejected >= 2);
    stop_all(server, coord);
}

#[test]
fn threads_plane_holds_the_same_wire_contract() {
    // The E13 ablation baseline must behave identically at the protocol
    // level: same replies, same structured rejects — so an A/B run
    // measures the connection plane, not accidental behavior drift.
    let max = 512;
    let (server, coord) = start(
        "oversize_threads",
        ServerConfig {
            conn_plane: ConnPlane::Threads,
            max_line_bytes: max,
            ..ServerConfig::default()
        },
    );
    let addr = server.addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap());
    let r = c.infer(&InferRequest::new(5).synthetic(77)).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.top1, expected_top1(MODEL, &frame_pixels(77), CLASSES));
    let stats = c.stats().unwrap();
    let conn = stats.get("conn").expect("threads plane reports conn too");
    assert_eq!(conn.get("plane").and_then(|v| v.as_str()), Some("threads"));

    assert_oversize_contract(&addr, max);
    assert!(server.conn_snapshot().oversize_rejected >= 2);

    drop(c);
    stop_all(server, coord);
}

/// The full `"conn"` stats-section contract plus the observability
/// round-trip (`{"cmd":"metrics"}` / `{"cmd":"trace"}`) — asserted
/// identically against one plane.  Run for both planes below: the
/// threads plane is the E13 ablation baseline and must expose the same
/// wire surface, not a subset.
fn assert_conn_section_and_obs_roundtrip(addr: &str, plane: &str, io_threads: usize) {
    let mut c = Client::connect(addr).unwrap();
    // Traffic first, so counters have something to show.
    for i in 0..4 {
        let r = c.infer(&InferRequest::new(i).synthetic(300 + i)).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }

    // Every documented "conn" field is present with a sane value.
    let stats = c.stats().unwrap();
    let conn = stats.get("conn").expect("stats must carry a conn section");
    assert_eq!(conn.get("plane").and_then(|v| v.as_str()), Some(plane));
    assert_eq!(conn.usize_of("io_threads").unwrap(), io_threads);
    assert!(conn.usize_of("connections").unwrap() >= 1, "we are connected");
    assert!(conn.usize_of("accepted").unwrap() >= 1);
    for key in [
        "rejected_at_capacity",
        "oversize_rejected",
        "backpressure_events",
        "idle_evicted",
        "in_flight",
        "peak_conn_in_flight",
        "completions",
    ] {
        assert!(conn.usize_of(key).is_ok(), "conn section missing {key}");
    }
    let bufs = conn.get("buffers").expect("conn section reports buffers");
    assert!(bufs.usize_of("free").is_ok());
    assert!(bufs.usize_of("outstanding").is_ok());
    let frames = conn.get("frames").expect("conn section reports frames");
    for key in ["negotiated", "received", "bytes", "rejected"] {
        assert!(frames.usize_of(key).is_ok(), "frames section missing {key}");
    }
    // The proc section (satellite of the same PR) rides on stats too.
    let proc = stats.get("proc").expect("stats must carry a proc section");
    assert!(proc.f64_of("rss_mb").unwrap() > 1.0);
    assert!(proc.usize_of("open_fds").unwrap() >= 3);

    // `{"cmd":"metrics"}` is a superset: same conn section, same proc
    // section, plus stages and trace counters.
    let m = c.metrics().unwrap();
    assert_eq!(m.get("ok").and_then(|v| v.as_bool()), Some(true));
    let mconn = m.get("conn").expect("metrics must carry the conn section");
    assert_eq!(mconn.get("plane").and_then(|v| v.as_str()), Some(plane));
    assert!(m.get("proc").is_some(), "metrics must carry the proc section");
    assert!(m.get("stages").and_then(|v| v.as_arr()).is_some());
    let t = m.get("trace").expect("metrics must carry trace counters");
    assert!(t.usize_of("begun").unwrap() >= 4);
    assert!(t.usize_of("rings").unwrap() >= 1);
    assert!(t.usize_of("sample_period").is_ok());

    // `{"cmd":"trace"}` answers a structured line on this plane too.
    let tr = c.trace(8).unwrap();
    assert_eq!(tr.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(tr.get("traces").and_then(|v| v.as_arr()).is_some());
    assert!(tr.get("slow").and_then(|v| v.as_arr()).is_some());
    drop(c);
}

#[test]
fn conn_stats_section_event_plane() {
    let (server, coord) = start("conn_section_event", ServerConfig::default());
    let io = ServerConfig::default().io_threads;
    assert_conn_section_and_obs_roundtrip(&server.addr().to_string(), "event", io);
    stop_all(server, coord);
}

#[test]
fn conn_stats_section_threads_plane() {
    let (server, coord) = start(
        "conn_section_threads",
        ServerConfig {
            conn_plane: ConnPlane::Threads,
            ..ServerConfig::default()
        },
    );
    // The threads plane has no fixed io fleet; it reports 0.
    assert_conn_section_and_obs_roundtrip(&server.addr().to_string(), "threads", 0);
    stop_all(server, coord);
}

/// Malformed-line contract shared by both planes and both wire parsers
/// (ISSUE 8): nesting past the depth bound and truncated JSON must come
/// back as structured `bad_request` lines — the parser rejects
/// structurally instead of recursing — and the connection stays usable
/// afterwards.  The stats line names the active parser so an A/B run
/// can prove which one answered.
fn assert_malformed_line_contract(addr: &str, wire_parser: &str) {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    // Deep nesting: well under the line-size limit, far over the depth
    // bound.
    let mut deep = String::from("{\"id\":1,\"image\":");
    deep.push_str(&"[".repeat(10_000));
    deep.push('\n');
    w.write_all(deep.as_bytes()).unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "no reject line");
    let j = Json::parse(&line).unwrap();
    assert_eq!(
        j.get("ok").and_then(|v| v.as_bool()),
        Some(false),
        "got: {line}"
    );
    assert_eq!(
        j.get("kind").and_then(|v| v.as_str()),
        Some("bad_request"),
        "got: {line}"
    );
    assert!(line.contains("depth"), "must name the depth bound: {line}");

    // Truncated request line: structured reject, same connection.
    w.write_all(b"{\"id\":1,\n").unwrap();
    line.clear();
    assert!(reader.read_line(&mut line).unwrap() > 0, "no reject line");
    assert!(line.contains("bad_request"), "got: {line}");

    // The connection survived both rejects and still serves.
    w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    line.clear();
    assert!(reader.read_line(&mut line).unwrap() > 0, "conn died after reject");
    assert!(line.contains("pong"), "got: {line}");

    // The stats line reports which parser is on duty.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    let conn = stats.get("conn").expect("stats must carry a conn section");
    assert_eq!(
        conn.get("wire_parser").and_then(|v| v.as_str()),
        Some(wire_parser),
        "conn section must name the active wire parser"
    );
    drop((c, reader, w));
}

#[test]
fn malformed_lines_structured_reject_both_planes_both_parsers() {
    for (plane, parser) in [
        (ConnPlane::Event, WireParser::Tape),
        (ConnPlane::Event, WireParser::Tree),
        (ConnPlane::Threads, WireParser::Tape),
        (ConnPlane::Threads, WireParser::Tree),
    ] {
        let tag = format!("malformed_{plane}_{parser}");
        let (server, coord) = start(
            &tag,
            ServerConfig {
                conn_plane: plane,
                wire_parser: parser,
                ..ServerConfig::default()
            },
        );
        assert_malformed_line_contract(&server.addr().to_string(), parser.as_str());
        stop_all(server, coord);
    }
}

/// Open a raw pipelining socket: a line reader plus the write half.
fn raw_conn(addr: &str) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    (BufReader::new(stream.try_clone().unwrap()), stream)
}

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "early EOF");
    Json::parse(&line).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
}

/// The tentpole contract, asserted per plane: after a hello handshake
/// one pipelined connection interleaves binary frames and JSON lines in
/// a single write; every request is answered exactly once, and a frame
/// carrying the same pixels as `{"synthetic":seed}` gets the same
/// answer (the two lanes are result-identical, not merely compatible).
fn assert_frames_interleave_with_json(addr: &str) {
    let (mut reader, mut w) = raw_conn(addr);

    let mut burst: Vec<u8> = Vec::new();
    burst.extend_from_slice(HELLO_FRAMES);
    let px1 = frame_rgb(501);
    burst.extend_from_slice(frame_header_line(1, px1.len(), HW, HW, 3).as_bytes());
    burst.extend_from_slice(&px1);
    burst.extend_from_slice(b"{\"id\":2,\"image\":{\"synthetic\":502}}\n");
    let px3 = frame_rgb(503);
    burst.extend_from_slice(frame_header_line(3, px3.len(), HW, HW, 3).as_bytes());
    burst.extend_from_slice(&px3);
    // Same pixels as id 2, via the frame lane: must match id 2's answer.
    let px4 = frame_rgb(502);
    burst.extend_from_slice(frame_header_line(4, px4.len(), HW, HW, 3).as_bytes());
    burst.extend_from_slice(&px4);
    w.write_all(&burst).unwrap();

    // Hello reply comes first (command replies are inline/in order).
    let hello = read_json_line(&mut reader);
    assert_eq!(hello.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(hello.usize_of("protocol_version").unwrap(), 1);
    assert_eq!(
        hello
            .get("negotiated")
            .and_then(|n| n.get("binary_frames"))
            .and_then(|v| v.as_bool()),
        Some(true),
        "hello must confirm the negotiation"
    );
    let features = hello.get("features").and_then(|v| v.as_arr()).unwrap();
    assert!(
        features.iter().any(|f| f.as_str() == Some("binary_frames")),
        "hello must advertise binary_frames"
    );

    // Inference replies may complete out of order: collect by id.
    let mut seen = std::collections::HashMap::new();
    for _ in 0..4 {
        let j = read_json_line(&mut reader);
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
        let id = j.usize_of("id").unwrap() as u64;
        let top1 = j.usize_of("top1").unwrap();
        assert!(seen.insert(id, top1).is_none(), "id {id} answered twice");
    }
    assert_eq!(seen[&1], expected_top1(MODEL, &frame_pixels(501), CLASSES));
    assert_eq!(seen[&2], expected_top1(MODEL, &frame_pixels(502), CLASSES));
    assert_eq!(seen[&3], expected_top1(MODEL, &frame_pixels(503), CLASSES));
    assert_eq!(seen[&4], seen[&2], "frame lane must answer like the JSON lane");

    // The stats line accounts for the lane.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    let frames = stats
        .get("conn")
        .and_then(|c| c.get("frames"))
        .expect("conn section reports frames");
    assert!(frames.usize_of("negotiated").unwrap() >= 1);
    assert!(frames.usize_of("received").unwrap() >= 3);
    assert!(frames.usize_of("bytes").unwrap() >= 3 * HW * HW * 3);
    assert_eq!(frames.usize_of("rejected").unwrap(), 0);
    drop((c, reader, w));
}

#[test]
fn binary_frames_interleaved_exactly_once_both_planes() {
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("frames_{plane}"),
            ServerConfig {
                conn_plane: plane,
                ..ServerConfig::default()
            },
        );
        assert_frames_interleave_with_json(&server.addr().to_string());
        stop_all(server, coord);
    }
}

#[test]
fn client_builder_ships_frames_end_to_end() {
    let (server, coord) = start("client_frames", ServerConfig::default());
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr).unwrap();

    let hello = c.hello(true).unwrap();
    assert_eq!(hello.protocol_version, 1);
    assert!(hello.binary_frames, "server must confirm the opt-in");
    assert!(hello.features.iter().any(|f| f == "binary_frames"));

    let rgb = frame_rgb(77);
    let r = c.infer(&InferRequest::new(9).frame(HW, HW, 3, &rgb)).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.top1, expected_top1(MODEL, &frame_pixels(77), CLASSES));

    drop(c);
    stop_all(server, coord);
}

/// Rejected-frame recovery contract, per plane: a frame on an
/// un-negotiated connection is `unsupported_feature`, a bad header on a
/// negotiated one is `bad_frame` — and when the declared `len` is
/// trustworthy the payload is skipped and the connection keeps serving.
fn assert_frame_rejects_recoverable(addr: &str) {
    // Un-negotiated: reject, skip the payload, keep serving.
    let (mut reader, mut w) = raw_conn(addr);
    let px = frame_rgb(1);
    let mut burst: Vec<u8> = Vec::new();
    burst.extend_from_slice(frame_header_line(1, px.len(), HW, HW, 3).as_bytes());
    burst.extend_from_slice(&px);
    burst.extend_from_slice(b"{\"cmd\":\"ping\"}\n");
    w.write_all(&burst).unwrap();
    let j = read_json_line(&mut reader);
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(
        j.get("kind").and_then(|v| v.as_str()),
        Some("unsupported_feature")
    );
    assert!(j.get("msg").and_then(|v| v.as_str()).unwrap().contains("hello"));
    let pong = read_json_line(&mut reader);
    assert_eq!(
        pong.get("pong").and_then(|v| v.as_bool()),
        Some(true),
        "connection must survive an unsupported_feature reject"
    );
    drop((reader, w));

    // Negotiated, header dims don't match len (len itself trustworthy):
    // bad_frame, payload skipped, connection recoverable.
    let (mut reader, mut w) = raw_conn(addr);
    let mut burst: Vec<u8> = Vec::new();
    burst.extend_from_slice(HELLO_FRAMES);
    burst.extend_from_slice(frame_header_line(2, 300, 9, 9, 3).as_bytes());
    burst.extend_from_slice(&[0u8; 300]);
    burst.extend_from_slice(b"{\"cmd\":\"ping\"}\n");
    w.write_all(&burst).unwrap();
    let hello = read_json_line(&mut reader);
    assert_eq!(hello.get("ok").and_then(|v| v.as_bool()), Some(true));
    let j = read_json_line(&mut reader);
    assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("bad_frame"));
    assert!(j.get("msg").and_then(|v| v.as_str()).is_some());
    let pong = read_json_line(&mut reader);
    assert_eq!(
        pong.get("pong").and_then(|v| v.as_bool()),
        Some(true),
        "connection must survive a bad_frame reject"
    );
    drop((reader, w));
}

#[test]
fn frame_rejects_are_structured_and_recoverable_both_planes() {
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("frame_rej_{plane}"),
            ServerConfig {
                conn_plane: plane,
                ..ServerConfig::default()
            },
        );
        assert_frame_rejects_recoverable(&server.addr().to_string());
        assert!(server.conn_snapshot().frames_rejected >= 2);
        stop_all(server, coord);
    }
}

/// A frame whose declared len exceeds `--max-frame-bytes` cannot be
/// skipped (the bound is exactly what made the len untrustworthy):
/// structured `bad_frame` naming the limit, then close.
fn assert_oversize_frame_rejected_and_closed(addr: &str) {
    let (mut reader, mut w) = raw_conn(addr);
    w.write_all(HELLO_FRAMES).unwrap();
    let hello = read_json_line(&mut reader);
    assert_eq!(hello.get("ok").and_then(|v| v.as_bool()), Some(true));
    w.write_all(frame_header_line(1, 1 << 20, 1024, 1024, 3).as_bytes())
        .unwrap();
    let j = read_json_line(&mut reader);
    assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("bad_frame"));
    assert!(
        j.get("msg").and_then(|v| v.as_str()).unwrap().contains("max-frame-bytes"),
        "reject must name the limit: {j:?}"
    );
    let mut line = String::new();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "must close: {line}");
    drop((reader, w));
}

#[test]
fn oversize_frame_structured_reject_both_planes() {
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("frame_big_{plane}"),
            ServerConfig {
                conn_plane: plane,
                max_frame_bytes: 64 * 1024,
                ..ServerConfig::default()
            },
        );
        assert_oversize_frame_rejected_and_closed(&server.addr().to_string());
        assert!(server.conn_snapshot().frames_rejected >= 1);
        stop_all(server, coord);
    }
}

#[test]
fn mid_frame_disconnect_leaves_server_healthy_both_planes() {
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("frame_cut_{plane}"),
            ServerConfig {
                conn_plane: plane,
                ..ServerConfig::default()
            },
        );
        let addr = server.addr().to_string();

        // Negotiate, declare a frame, send half the payload, vanish.
        let (mut reader, mut w) = raw_conn(&addr);
        w.write_all(HELLO_FRAMES).unwrap();
        let hello = read_json_line(&mut reader);
        assert_eq!(hello.get("ok").and_then(|v| v.as_bool()), Some(true));
        let px = frame_rgb(5);
        w.write_all(frame_header_line(1, px.len(), HW, HW, 3).as_bytes())
            .unwrap();
        w.write_all(&px[..px.len() / 2]).unwrap();
        drop((reader, w));

        // The abandoned connection is reaped and new clients are served.
        assert!(
            wait_until(Duration::from_secs(10), || {
                server.conn_snapshot().connections == 0
            }),
            "half-sent frame wedged the connection: {:?}",
            server.conn_snapshot()
        );
        let mut c = Client::connect(&addr).unwrap();
        let r = c.infer(&InferRequest::new(2).synthetic(6)).unwrap();
        assert!(r.ok, "server unhealthy after mid-frame disconnect: {:?}", r.error);
        assert_eq!(server.conn_snapshot().in_flight, 0, "leaked in-flight slot");

        drop(c);
        stop_all(server, coord);
    }
}

/// Unified error schema (ISSUE 9 satellite): every reject the server
/// can emit carries `ok:false`, a `kind` from the documented closed
/// set, and a human `msg` — asserted across reject paths on both
/// planes.  The deprecated `error` alias (ISSUE 10 cleanup) is off the
/// default wire and only returns under `--compat-error-alias`.
fn assert_error_schema_fmt(addr: &str, compat_alias: bool) {
    let check = |j: &Json, expect_kind: &str| {
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false), "{j:?}");
        let kind = j.get("kind").and_then(|v| v.as_str()).expect("reject has kind");
        assert_eq!(kind, expect_kind, "{j:?}");
        assert!(
            zuluko::server::protocol::ERROR_KINDS.contains(&kind),
            "kind {kind} not in the documented set"
        );
        let msg = j.get("msg").and_then(|v| v.as_str()).expect("reject has msg");
        assert!(!msg.is_empty());
        if compat_alias {
            assert_eq!(
                j.get("error").and_then(|v| v.as_str()),
                Some(msg),
                "compat alias must duplicate msg: {j:?}"
            );
        } else {
            assert!(
                j.get("error").is_none(),
                "deprecated alias leaked onto the default wire: {j:?}"
            );
        }
    };

    let (mut reader, mut w) = raw_conn(addr);
    // bad_request: malformed JSON.
    w.write_all(b"{nope\n").unwrap();
    check(&read_json_line(&mut reader), "bad_request");
    // unknown_model.
    w.write_all(b"{\"id\":1,\"image\":{\"synthetic\":1},\"model\":\"ghost\"}\n")
        .unwrap();
    check(&read_json_line(&mut reader), "unknown_model");
    // unsupported_feature: frame before hello (resyncable — skipped).
    w.write_all(frame_header_line(2, 3, 1, 1, 3).as_bytes()).unwrap();
    w.write_all(&[0u8; 3]).unwrap();
    check(&read_json_line(&mut reader), "unsupported_feature");
    // bad_frame: negotiated but inconsistent header.
    w.write_all(HELLO_FRAMES).unwrap();
    let hello = read_json_line(&mut reader);
    assert_eq!(hello.get("ok").and_then(|v| v.as_bool()), Some(true));
    w.write_all(frame_header_line(3, 3, 2, 2, 3).as_bytes()).unwrap();
    w.write_all(&[0u8; 3]).unwrap();
    check(&read_json_line(&mut reader), "bad_frame");
    // The connection survived all four rejects.
    w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let pong = read_json_line(&mut reader);
    assert_eq!(pong.get("pong").and_then(|v| v.as_bool()), Some(true));
    drop((reader, w));
}

#[test]
fn error_schema_unified_both_planes() {
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("errschema_{plane}"),
            ServerConfig {
                conn_plane: plane,
                ..ServerConfig::default()
            },
        );
        assert_error_schema_fmt(&server.addr().to_string(), false);
        stop_all(server, coord);
    }
}

#[test]
fn compat_error_alias_restores_deprecated_field_both_planes() {
    // `--compat-error-alias` buys old clients one more release: every
    // reject re-grows the `error` duplicate of `msg`, on both planes.
    for plane in [ConnPlane::Event, ConnPlane::Threads] {
        let (server, coord) = start(
            &format!("erralias_{plane}"),
            ServerConfig {
                conn_plane: plane,
                compat_error_alias: true,
                ..ServerConfig::default()
            },
        );
        assert_error_schema_fmt(&server.addr().to_string(), true);
        stop_all(server, coord);
    }
}

#[test]
fn event_plane_thread_count_independent_of_connections() {
    let (server, coord) = start(
        "fleet",
        ServerConfig {
            io_threads: 2,
            max_connections: 512,
            ..ServerConfig::default()
        },
    );
    let addr = server.addr();

    // Hold 200 concurrent connections, each serving a round-trip.
    const CONNS: usize = 200;
    let mut held = Vec::with_capacity(CONNS);
    for i in 0..CONNS {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "conn {i} lost");
        assert!(line.contains("pong"), "conn {i}: {line}");
        held.push((reader, w));
    }
    assert_eq!(server.conn_snapshot().connections, CONNS);

    // Thread count stays a small constant — not one per connection.
    // (Other tests in this process run their own 2-thread reactors
    // concurrently, so bound rather than demand exact equality; 200
    // thread-per-conn handlers would blow far past this.)
    let io = threads_named("zuluko-io-");
    assert!(io >= 2, "our 2 io threads must exist (saw {io})");
    assert!(
        io < CONNS / 4,
        "io thread count grew with connections ({io} for {CONNS} conns)"
    );
    assert!(threads_named("zuluko-accept") >= 1);

    drop(held);
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.conn_snapshot().connections == 0
        }),
        "connections not released on close: {}",
        server.conn_snapshot().connections
    );
    stop_all(server, coord);
}
