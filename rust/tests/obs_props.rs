//! Trace-plane property tests (DESIGN.md §10): the invariants the
//! seqlock ring and sampling hub must hold under any interleaving —
//!
//! * a push never blocks and the ring never retains more than its
//!   capacity, no matter how many writers race;
//! * a snapshot never returns a torn span (a slot mixing two writes)
//!   and never more than `min(k, capacity)` entries;
//! * with sampling compiled in but sampled out (rate 0), completed
//!   requests leave zero residue — no retained timelines, no slow-log
//!   entries, no recorded count;
//! * with rate 1 every completion is retained (up to ring capacity).
//!
//! These run hot (hundreds of thousands of pushes) but allocation-free
//! on the writer side, so they finish in well under a second each.

use std::time::Instant;

use zuluko::obs::{flag, ObsHub, Span, Stage, TraceRing, STAGES};

/// A self-checkable span: every word is a pure function of `v`, so a
/// torn read (two writers' words mixed in one snapshot entry) breaks
/// the relation with overwhelming probability.
fn coded_span(v: u64) -> Span {
    let mut marks = [0u64; STAGES];
    for (i, m) in marks.iter_mut().enumerate() {
        *m = v.wrapping_mul(31).wrapping_add(i as u64 + 1).max(1);
    }
    Span {
        id: v,
        marks,
        deadline_ns: v.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        flags: v ^ 0xABCD,
    }
}

fn assert_not_torn(s: &Span) {
    let v = s.id;
    for (i, &m) in s.marks.iter().enumerate() {
        assert_eq!(
            m,
            v.wrapping_mul(31).wrapping_add(i as u64 + 1).max(1),
            "torn mark {i} in span coded {v}"
        );
    }
    assert_eq!(s.deadline_ns, v.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    assert_eq!(s.flags, v ^ 0xABCD, "torn flags in span coded {v}");
}

#[test]
fn concurrent_pushes_never_block_never_exceed_cap_never_tear() {
    const CAP: usize = 64;
    const WRITERS: u64 = 8;
    const PER: u64 = 20_000;
    let ring = TraceRing::new(CAP);
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for t in 0..WRITERS {
            let ring = &ring;
            scope.spawn(move || {
                for i in 0..PER {
                    ring.push(&coded_span((t << 32) | i));
                }
            });
        }
        // A racing reader: every snapshot it takes mid-storm must be
        // bounded and tear-free (the seqlock skips in-progress slots).
        let ring = &ring;
        scope.spawn(move || {
            for _ in 0..500 {
                let snap = ring.snapshot(usize::MAX);
                assert!(snap.len() <= CAP, "snapshot over cap: {}", snap.len());
                for s in &snap {
                    assert_not_torn(s);
                }
            }
        });
    });

    // 160k contended pushes: seconds would mean a writer blocked.
    assert!(
        t0.elapsed().as_secs() < 20,
        "pushes took {:?} — writers are blocking",
        t0.elapsed()
    );
    assert!(ring.len() <= CAP);
    let fin = ring.snapshot(usize::MAX);
    assert!(fin.len() <= CAP);
    for s in &fin {
        assert_not_torn(s);
    }
}

#[test]
fn snapshot_is_bounded_by_k_and_cap_and_keeps_newest() {
    const CAP: usize = 32;
    let ring = TraceRing::new(CAP);
    assert!(ring.is_empty());
    assert_eq!(ring.capacity(), CAP);

    const N: u64 = (CAP as u64) * 10;
    for v in 0..N {
        ring.push(&coded_span(v));
    }
    assert_eq!(ring.len(), CAP, "ring len must saturate at capacity");

    for k in [0usize, 1, CAP / 2, CAP, CAP * 4, usize::MAX] {
        let snap = ring.snapshot(k);
        assert!(snap.len() <= k.min(CAP), "k={k} gave {}", snap.len());
    }

    // A full snapshot after sequential pushes is exactly the newest
    // CAP spans — older ones were overwritten, none duplicated.
    let mut ids: Vec<u64> = ring.snapshot(CAP).iter().map(|s| s.id).collect();
    ids.sort_unstable();
    let want: Vec<u64> = (N - CAP as u64..N).collect();
    assert_eq!(ids, want, "snapshot lost or duplicated recent spans");
}

/// Drive one span through all eight stages and complete it on the hub.
fn run_span(hub: &ObsHub, deadline_ns: u64) -> Span {
    let mut s = hub.begin();
    s.id = 7;
    s.deadline_ns = deadline_ns;
    for stage in [
        Stage::Parsed,
        Stage::Admitted,
        Stage::Dequeued,
        Stage::BatchFormed,
        Stage::InferStart,
        Stage::InferDone,
        Stage::ReplyFlushed,
    ] {
        s.set(stage, hub.now_ns());
    }
    hub.complete(&mut s, s.id as usize);
    s
}

#[test]
fn sampled_out_requests_leave_zero_residue() {
    // Rate 0: tracing compiled in, every request sampled out.  Stay
    // under SLOW_WARMUP so the tail estimator can never flag anomalies.
    let hub = ObsHub::new(0.0, 128, 64, 2);
    const N: u64 = 400;
    for _ in 0..N {
        let s = run_span(&hub, 0);
        assert!(!s.sampled());
        assert!(s.monotonic(), "stamps out of order: {s:?}");
    }
    assert!(hub.traces(10_000).is_empty(), "residue in trace rings");
    assert!(hub.slow_log(10_000).is_empty(), "residue in slow log");
    let c = hub.counters();
    assert_eq!(c.begun, N);
    assert_eq!(c.completed, N);
    assert_eq!(c.recorded, 0);
    assert_eq!(c.sampled_out, N);
    assert_eq!(c.anomalies, 0);
    assert_eq!(c.sample_period, 0);
}

#[test]
fn rate_one_retains_every_completion_up_to_capacity() {
    let hub = ObsHub::new(1.0, 1024, 64, 2);
    const N: u64 = 100;
    for _ in 0..N {
        let s = run_span(&hub, 0);
        assert!(s.sampled());
    }
    let traces = hub.traces(10_000);
    assert_eq!(traces.len() as u64, N, "rate 1 must retain everything");
    for s in &traces {
        assert!(s.monotonic());
        assert_eq!(
            s.marks.iter().filter(|&&m| m != 0).count(),
            STAGES,
            "retained span missing stage marks: {s:?}"
        );
    }
    let c = hub.counters();
    assert_eq!(c.recorded, N);
    assert_eq!(c.sampled_out, 0);
    assert_eq!(c.sample_period, 1);
}

#[test]
fn hub_stays_bounded_under_concurrent_anomalies() {
    // Tiny rings, every span both sampled and deadline-missed: the
    // worst retention case.  Memory must stay bounded by the configured
    // capacities no matter how many requests flow.
    const RING: usize = 32;
    const SLOW: usize = 16;
    let hub = ObsHub::new(1.0, RING, SLOW, 2);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let hub = &hub;
            scope.spawn(move || {
                for _ in 0..5_000 {
                    // 1ns budget: every span misses its deadline.
                    let s = run_span(hub, 1);
                    assert!(s.flags & flag::DEADLINE_MISSED != 0);
                }
            });
        }
    });
    assert!(hub.traces(usize::MAX).len() <= 2 * RING);
    assert!(hub.slow_log(usize::MAX).len() <= SLOW);
    let c = hub.counters();
    assert_eq!(c.completed, 20_000);
    assert_eq!(c.anomalies, 20_000);
}
