//! End-to-end: coordinator + TCP server + client over a real engine.
//!
//! Uses the fused ACL engine (fastest compile) and synthetic images.
//! Verifies: responses arrive, ids echo, concurrent clients batch
//! together, stats/ping work, and backpressure surfaces as an error
//! rather than a hang.

use std::sync::Arc;
use std::time::Duration;

use zuluko::config::Config;
use zuluko::coordinator::{Coordinator, SubmitError};
use zuluko::engine::EngineKind;
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::tensor::Tensor;

fn artifacts_ready() -> bool {
    zuluko::artifacts_dir().join("manifest.json").exists()
}

fn test_config() -> Config {
    Config {
        engine: EngineKind::AclFused,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(30),
        queue_capacity: 16,
        listen: "127.0.0.1:0".into(),
        ..Config::default()
    }
}

#[test]
fn serve_infer_stats_ping_roundtrip() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let coord = Arc::new(Coordinator::start(&test_config()).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap());

    let r = c.infer(&InferRequest::new(7).synthetic(12345)).unwrap();
    assert!(r.ok, "error: {:?}", r.error);
    assert_eq!(r.id, 7);
    assert!(r.total_ms > 0.0);
    assert!(r.batch >= 1);
    assert!(r.top1 < 1000);

    // Same seed -> same class (determinism through the whole wire path).
    let r2 = c.infer(&InferRequest::new(8).synthetic(12345)).unwrap();
    assert_eq!(r2.top1, r.top1);

    let stats = c.stats().unwrap();
    assert_eq!(stats.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(stats.usize_of("completed").unwrap() >= 2);

    drop(c); // close the connection so its handler thread releases the Arc
    server.stop();
    // Handler threads may take a beat to observe EOF and drop their clone.
    let mut coord = coord;
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    let reports = coord.shutdown();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].images >= 2);
}

#[test]
fn concurrent_clients_get_batched() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let coord = Arc::new(Coordinator::start(&test_config()).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    // 4 clients fire simultaneously; the 30ms batch window should coalesce
    // at least some of them (assert >= one multi-request batch).
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.infer(&InferRequest::new(i).synthetic(1000 + i)).unwrap()
            })
        })
        .collect();
    let replies: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(replies.iter().all(|r| r.ok));
    let max_batch = replies.iter().map(|r| r.batch).max().unwrap();
    assert!(
        max_batch >= 2,
        "no batching happened (batches: {:?})",
        replies.iter().map(|r| r.batch).collect::<Vec<_>>()
    );

    server.stop();
}

#[test]
fn malformed_requests_get_error_lines_not_disconnects() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let coord = Arc::new(Coordinator::start(&test_config()).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();

    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    for bad in ["garbage\n", "{\"id\":1}\n", "{\"cmd\":\"rm -rf\"}\n"] {
        w.write_all(bad.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "got: {line}");
    }
    // Connection still alive for a good request afterwards.
    w.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"));

    server.stop();
}

#[test]
fn backpressure_rejects_when_saturated() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // Tiny queue; saturate with instant submissions at coordinator level.
    let cfg = Config {
        queue_capacity: 4,
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        ..test_config()
    };
    let coord = Coordinator::start(&cfg).unwrap();
    let img = || Tensor::random(&[227, 227, 3], 1);

    let mut receivers = Vec::new();
    let mut overloaded = false;
    // Burst far beyond capacity; at least one must bounce.
    for _ in 0..64 {
        match coord.submit(img()) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitError::Overloaded) => {
                overloaded = true;
                break;
            }
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(overloaded, "queue of 4 absorbed 64 instant submissions");
    // Everything admitted still completes (no lost requests).
    for rx in receivers {
        let resp = rx.recv().unwrap();
        assert!(resp.is_ok(), "{:?}", resp.error);
    }
    let stats = coord.stats();
    assert!(stats.rejected >= 1);
    coord.shutdown();
}

#[test]
fn bad_input_shape_rejected_at_submit() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let coord = Coordinator::start(&test_config()).unwrap();
    match coord.submit(Tensor::zeros(&[100, 100, 3])) {
        Err(SubmitError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    coord.shutdown();
}
