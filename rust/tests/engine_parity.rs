//! Integration: every engine produces the oracle's answer.
//!
//! Fig 3's comparison is only meaningful if the ACL engine and the
//! TF-baseline compute the *same function* — these tests pin all five
//! engine variants to the JAX golden outputs.

use zuluko::engine::{build, EngineKind};
use zuluko::metrics::ledger::Group;
use zuluko::runtime::Manifest;
use zuluko::tensor::Tensor;

fn setup() -> Option<(Manifest, Tensor, Tensor)> {
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    let m = Manifest::load(&dir).expect("manifest");
    let input =
        Tensor::from_f32_file(&m.path(&m.golden.input), &[1, 227, 227, 3]).unwrap();
    let golden = Tensor::from_f32_file(&m.path(&m.golden.probs), &[1, 1000]).unwrap();
    Some((m, input, golden))
}

fn check_engine(kind: EngineKind, tol: f32) {
    let Some((m, input, golden)) = setup() else { return };
    let mut e = build(kind, &m).expect("build engine");
    let probs = e.infer(&input).expect("infer");
    assert_eq!(probs.shape(), &[1, 1000]);
    let (abs, _) = probs.max_abs_rel_diff(&golden).unwrap();
    assert!(abs < tol, "{}: drift {abs} (tol {tol})", e.name());
    assert_eq!(probs.argmax(), m.golden.top1, "{} top-1", e.name());
    // Ledger must have recorded work.
    assert!(!e.ledger().is_empty(), "{} ledger empty", e.name());
}

#[test]
fn acl_staged_matches_golden() {
    check_engine(EngineKind::AclStaged, 1e-3);
}

#[test]
fn acl_fused_matches_golden() {
    check_engine(EngineKind::AclFused, 1e-3);
}

#[test]
fn acl_probe_matches_golden() {
    check_engine(EngineKind::AclProbe, 1e-3);
}

#[test]
fn tf_baseline_matches_golden() {
    check_engine(EngineKind::TfBaseline, 1e-3);
}

#[test]
fn quant_engine_matches_quant_golden() {
    let Some((m, input, _)) = setup() else { return };
    let golden_q8 =
        Tensor::from_f32_file(&m.path(&m.golden.probs_q8), &[1, 1000]).unwrap();
    let mut e = build(EngineKind::Quant, &m).unwrap();
    let probs = e.infer(&input).unwrap();
    let (abs, _) = probs.max_abs_rel_diff(&golden_q8).unwrap();
    assert!(abs < 1e-3, "quant drift {abs}");
    assert_eq!(probs.argmax(), m.golden.top1_q8);
}

#[test]
fn quant_approximates_fp32_probs() {
    // The 'trade accuracy for performance' bound: int8 probs stay close
    // to fp32 probs on the golden image.
    let Some((m, input, golden)) = setup() else { return };
    let mut e = build(EngineKind::Quant, &m).unwrap();
    let probs = e.infer(&input).unwrap();
    let (abs, _) = probs.max_abs_rel_diff(&golden).unwrap();
    assert!(abs < 0.05, "quantization error on probs too large: {abs}");
    assert_eq!(probs.argmax(), m.golden.top1, "quantization flipped top-1");
}

#[test]
fn engines_agree_pairwise() {
    let Some((m, input, _)) = setup() else { return };
    let mut acl = build(EngineKind::AclStaged, &m).unwrap();
    let mut tf = build(EngineKind::TfBaseline, &m).unwrap();
    let a = acl.infer(&input).unwrap();
    let t = tf.infer(&input).unwrap();
    let (abs, _) = a.max_abs_rel_diff(&t).unwrap();
    assert!(abs < 1e-3, "acl vs tf drift {abs}");
}

#[test]
fn tf_ledger_covers_all_groups_and_ops() {
    let Some((m, input, _)) = setup() else { return };
    let mut tf = build(EngineKind::TfBaseline, &m).unwrap();
    tf.infer(&input).unwrap();
    let l = tf.ledger();
    let rows = l.rows();
    assert_eq!(rows.len(), 66, "one ledger row per op");
    assert!(l.group_total(Group::Group1) > std::time::Duration::ZERO);
    assert!(l.group_total(Group::Group2) > std::time::Duration::ZERO);
    assert_eq!(l.group_total(Group::Quant), std::time::Duration::ZERO);
    // Concats exist in the baseline (the copies ACL eliminates).
    assert_eq!(rows.iter().filter(|r| r.0.ends_with("_concat")).count(), 8);
}

#[test]
fn quant_ledger_has_quant_overhead_group() {
    let Some((m, input, _)) = setup() else { return };
    let mut q = build(EngineKind::Quant, &m).unwrap();
    q.infer(&input).unwrap();
    let l = q.ledger();
    assert_eq!(l.rows().len(), 118);
    assert!(l.group_total(Group::Quant) > std::time::Duration::ZERO,
            "quant overhead must be measured");
}

#[test]
fn probe_ledger_group_split_covers_both() {
    let Some((m, input, _)) = setup() else { return };
    let mut e = build(EngineKind::AclProbe, &m).unwrap();
    e.infer(&input).unwrap();
    let l = e.ledger();
    assert_eq!(l.rows().len(), 15);
    assert!(l.group_total(Group::Group1) > std::time::Duration::ZERO);
    assert!(l.group_total(Group::Group2) > std::time::Duration::ZERO);
}

#[test]
fn acl_batch_sizes_all_work() {
    let Some((m, input, golden)) = setup() else { return };
    let mut e = build(EngineKind::AclStaged, &m).unwrap();
    let single = input.clone().reshape(&[227, 227, 3]).unwrap();
    for &b in &m.batch_sizes {
        let refs: Vec<&Tensor> = (0..b).map(|_| &single).collect();
        let batch = Tensor::stack(&refs).unwrap();
        let probs = e.infer(&batch).unwrap();
        assert_eq!(probs.shape(), &[b, 1000]);
        for row in probs.unstack().unwrap() {
            let row = row.reshape(&[1, 1000]).unwrap();
            let (abs, _) = row.max_abs_rel_diff(&golden).unwrap();
            assert!(abs < 1e-3, "b{b} row drift {abs}");
        }
    }
}

#[test]
fn acl_rejects_unsupported_batch() {
    let Some((m, _, _)) = setup() else { return };
    let mut e = build(EngineKind::AclStaged, &m).unwrap();
    let batch = Tensor::zeros(&[3, 227, 227, 3]); // 3 not in {1,2,4,8}
    assert!(e.infer(&batch).is_err());
}
