//! Replica-snapshot trust model (DESIGN.md §11), proven adversarially
//! over the sim engine — no artifacts or XLA needed, so these run
//! everywhere including CI:
//!
//! * a corrupt snapshot (truncation, bit-flip, version skew, garbage)
//!   NEVER panics and NEVER serves — every boot falls back to a cold
//!   build with byte-identical answers, counting a snapshot miss;
//! * a stale snapshot (artifacts changed underneath it) self-invalidates
//!   via the content hash and the new artifacts are what gets served;
//! * snapshot-built and cold-built replicas answer identically (the sim
//!   oracle makes "wrong weights" directly observable as a wrong class);
//! * concurrent refresh is atomic: readers racing writers see a whole
//!   snapshot or a clean error, never a misparse;
//! * a no-op `{"cmd":"reload"}` (unchanged artifacts) reports
//!   `rebuilt:false` with zero warm time and no probe build;
//! * predictive warm-up: a hot queue's arrival rate makes idle workers
//!   prefetch-build replicas before traffic lands on them.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use zuluko::config::{Config, SnapshotMode};
use zuluko::coordinator::{Coordinator, ModelStatsSnapshot};
use zuluko::engine::sim::expected_top1;
use zuluko::engine::EngineKind;
use zuluko::policy::{bytes_key_parts, Slo};
use zuluko::runtime::snapshot::SNAPSHOT_FILE;
use zuluko::runtime::{Manifest, ReplicaSnapshot};
use zuluko::server::client::{Client, InferRequest};
use zuluko::server::Server;
use zuluko::tensor::image::Image;
use zuluko::tensor::Tensor;

const HW: usize = 32;
const CLASSES: usize = 100;
const MODEL: &str = "m";

/// A fresh synthetic-model artifacts dir, unique per test.
fn model_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zuluko_snapshot_props_{tag}_{}",
        std::process::id()
    ));
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, CLASSES, HW, &[1, 2, 4])
        .unwrap();
    dir
}

/// One sim model, response cache off so every request runs an engine.
fn sim_cfg(dir: &Path, mode: SnapshotMode) -> Config {
    let mut cfg = Config {
        engine: EngineKind::Sim,
        workers: 1,
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 64,
        ..Config::default()
    };
    cfg.policy.cache_capacity = 0;
    cfg.snapshots = mode;
    cfg.registry.upsert(MODEL, dir.to_path_buf());
    cfg.registry.default_model = Some(MODEL.to_string());
    cfg.validate().unwrap();
    cfg
}

/// Exactly the pixels the stack decodes for `{"synthetic": seed}`.
fn frame_pixels(seed: u64) -> Vec<f32> {
    let img = Image::synthetic(HW, HW, seed);
    let mut buf = vec![0.0f32; HW * HW * 3];
    img.to_input_into(&mut buf);
    buf
}

fn frame_tensor(seed: u64) -> Tensor {
    Tensor::new(&[HW, HW, 3], frame_pixels(seed)).unwrap()
}

fn model_stats(coord: &Coordinator) -> ModelStatsSnapshot {
    coord
        .stats()
        .models
        .into_iter()
        .find(|m| m.model == MODEL)
        .expect("model row in stats")
}

/// Serve `n` distinct seeds through a coordinator, asserting every
/// answer against the sim oracle, and return the top1 sequence.
fn serve_seeds(coord: &Coordinator, base: u64, n: u64, label: &str) -> Vec<usize> {
    (0..n)
        .map(|i| {
            let seed = base + i;
            let r = coord
                .submit_model(Some(MODEL), frame_tensor(seed), Slo::default())
                .unwrap()
                .recv()
                .unwrap();
            assert!(r.is_ok(), "{label}: seed {seed} failed: {:?}", r.error);
            assert_eq!(
                r.top1,
                expected_top1(MODEL, &frame_pixels(seed), CLASSES),
                "{label}: seed {seed} served the wrong class"
            );
            r.top1
        })
        .collect()
}

fn stop_all(server: Server, mut coord: Arc<Coordinator>) {
    server.stop();
    let coord = loop {
        match Arc::try_unwrap(coord) {
            Ok(c) => break c,
            Err(arc) => {
                coord = arc;
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    coord.shutdown();
}

/// Encoded-snapshot sweep: every truncation point and every flipped
/// byte must decode to a clean `Err` — never a panic, never an `Ok`
/// over corrupt bytes (the trailing checksum is verified first).
#[test]
fn decode_rejects_every_truncation_and_bitflip() {
    let dir = model_dir("sweep");
    let m = Manifest::load(&dir).unwrap();
    let bytes = ReplicaSnapshot::capture(&m, &[EngineKind::Sim])
        .unwrap()
        .encode();

    for keep in (0..bytes.len()).step_by(7).chain([bytes.len() - 1]) {
        assert!(
            ReplicaSnapshot::decode(&bytes[..keep], &dir).is_err(),
            "decode accepted a {keep}-byte prefix of {}",
            bytes.len()
        );
    }
    for pos in (0..bytes.len()).step_by(11) {
        for bit in [0x01u8, 0x80] {
            let mut b = bytes.clone();
            b[pos] ^= bit;
            assert!(
                ReplicaSnapshot::decode(&b, &dir).is_err(),
                "decode accepted a flip of bit {bit:#x} at byte {pos}"
            );
        }
    }
    // The untouched bytes still decode — the sweep tested the codec,
    // not a broken fixture.
    assert!(ReplicaSnapshot::decode(&bytes, &dir).is_ok());
}

/// Differential: cold-built (snapshots off), capture-then-serve (first
/// boot on), snapshot-built (second boot on), and refresh-mode replicas
/// all answer identically.
#[test]
fn snapshot_and_cold_builds_serve_identically() {
    let dir = model_dir("diff");
    let snap_path = dir.join(SNAPSHOT_FILE);

    // Ablation baseline: snapshots off — no file appears.
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::Off)).unwrap();
    let cold = serve_seeds(&coord, 100, 8, "off");
    assert!(!snap_path.exists(), "snapshots=off must not write {SNAPSHOT_FILE}");
    assert_eq!(model_stats(&coord).snapshot_hits, 0);
    assert_eq!(model_stats(&coord).snapshot_misses, 0);
    coord.shutdown();

    // First boot with snapshots on: cold build (a miss), then capture.
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
    let first = serve_seeds(&coord, 100, 8, "on/first");
    assert!(snap_path.exists(), "first boot must write the snapshot");
    assert!(model_stats(&coord).snapshot_misses >= 1);
    coord.shutdown();

    // Second boot: replica construction comes from the snapshot.
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
    let second = serve_seeds(&coord, 100, 8, "on/second");
    assert!(
        model_stats(&coord).snapshot_hits >= 1,
        "second boot never loaded the snapshot: {:?}",
        model_stats(&coord)
    );
    coord.shutdown();

    // Refresh: always cold-build, rewrite the file.
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::Refresh)).unwrap();
    let refreshed = serve_seeds(&coord, 100, 8, "refresh");
    coord.shutdown();

    assert_eq!(cold, first, "capture boot diverged from the cold baseline");
    assert_eq!(cold, second, "snapshot-built replica diverged from cold");
    assert_eq!(cold, refreshed, "refresh-built replica diverged from cold");
}

/// Every corruption of the on-disk snapshot degrades to a cold build —
/// the boot serves correct answers and counts a miss, never panicking,
/// never trusting the corrupt bytes.
#[test]
fn corrupt_snapshots_always_fall_back_to_cold_build() {
    let dir = model_dir("corrupt");
    let path = dir.join(SNAPSHOT_FILE);

    // Seed a valid snapshot to corrupt.
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
    serve_seeds(&coord, 200, 2, "seed");
    coord.shutdown();
    let valid = std::fs::read(&path).unwrap();

    let truncated_half = valid[..valid.len() / 2].to_vec();
    let mut flipped = valid.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x20;
    // Version skew with a re-sealed checksum, so only the version check
    // can object (the byte after the 8-byte magic is the version LE).
    let mut skewed = valid.clone();
    skewed[8] = 99;
    let n = skewed.len();
    let sum = bytes_key_parts(&[&skewed[..n - 8]]);
    skewed[n - 8..].copy_from_slice(&sum.to_le_bytes());

    let variants: &[(&str, &[u8])] = &[
        ("empty file", &[]),
        ("truncated to half", &truncated_half),
        ("single bit flip", &flipped),
        ("version skew", &skewed),
        ("garbage", b"ZSNP but not really a snapshot at all"),
    ];
    for (label, bytes) in variants {
        std::fs::write(&path, bytes).unwrap();
        let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
        serve_seeds(&coord, 300, 4, label);
        let m = model_stats(&coord);
        // The probe found no usable snapshot (miss); worker replicas may
        // still count hits afterwards — they build from the in-memory
        // snapshot the cold probe re-captured, which is the fast path
        // working as designed, not the corrupt file being trusted.
        assert!(
            m.snapshot_misses >= 1,
            "{label}: corrupt snapshot must count a miss, got {m:?}"
        );
        coord.shutdown();
        // The boot healed the file: the next load sees a valid snapshot.
        assert!(
            ReplicaSnapshot::load(&dir).is_ok(),
            "{label}: boot did not rewrite a valid snapshot"
        );
    }
}

/// Artifacts mutated after capture: the content hash refuses the old
/// snapshot and the NEW artifacts are what gets served — a stale
/// snapshot can never pin old weights or old sizing.
#[test]
fn stale_snapshot_self_invalidates_and_new_artifacts_win() {
    let dir = model_dir("stale");
    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
    serve_seeds(&coord, 400, 2, "before");
    coord.shutdown();
    assert!(dir.join(SNAPSHOT_FILE).exists());

    // Same model name, different class count: answers must change.
    const NEW_CLASSES: usize = 37;
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, NEW_CLASSES, HW, &[1, 2, 4])
        .unwrap();

    let coord = Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap();
    for i in 0..4u64 {
        let seed = 500 + i;
        let r = coord
            .submit_model(Some(MODEL), frame_tensor(seed), Slo::default())
            .unwrap()
            .recv()
            .unwrap();
        assert!(r.is_ok(), "stale: {:?}", r.error);
        assert_eq!(
            r.top1,
            expected_top1(MODEL, &frame_pixels(seed), NEW_CLASSES),
            "stale snapshot served the old artifacts"
        );
    }
    let m = model_stats(&coord);
    assert!(m.snapshot_misses >= 1, "stale load must count a miss: {m:?}");
    coord.shutdown();

    // The refreshed snapshot reflects the new artifacts.
    assert_eq!(
        ReplicaSnapshot::load(&dir).unwrap().num_classes,
        NEW_CLASSES,
        "boot did not refresh the stale snapshot"
    );
}

/// Readers racing concurrent refresh writers: every successful load is
/// a whole, correct snapshot; every race loss is a clean error (which
/// callers treat as cold-build); nothing panics.
#[test]
fn concurrent_refresh_never_yields_a_torn_snapshot() {
    let dir = model_dir("refresh_race");
    let m = Manifest::load(&dir).unwrap();
    let snap = Arc::new(ReplicaSnapshot::capture(&m, &[EngineKind::Sim]).unwrap());
    snap.write(&dir).unwrap();
    let want = snap.content_hash;

    let stop = Arc::new(AtomicBool::new(false));
    let mut writers = Vec::new();
    let mut wrote = 0usize;
    for _ in 0..3 {
        let snap = snap.clone();
        let dir = dir.clone();
        writers.push(std::thread::spawn(move || {
            // Writers share one tmp path, so a racing rename can make a
            // write fail (ENOENT) — that is allowed; a torn *read* is not.
            (0..50).filter(|_| snap.write(&dir).is_ok()).count()
        }));
    }
    let reader = {
        let dir = dir.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut oks = 0usize;
            while !stop.load(Ordering::Relaxed) {
                if let Ok(s) = ReplicaSnapshot::load(&dir) {
                    assert_eq!(s.content_hash, want, "torn snapshot passed validation");
                    assert_eq!(s.num_classes, CLASSES);
                    oks += 1;
                }
            }
            oks
        })
    };
    for w in writers {
        wrote += w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let oks = reader.join().unwrap();
    assert!(wrote >= 1, "no refresh ever landed");
    assert!(oks >= 1, "no load ever succeeded under concurrent refresh");
}

/// Wire-level no-op reload (ISSUE 10 bugfix): unchanged artifacts bump
/// the generation without a probe build — `rebuilt:false`, zero warm
/// time — and a real artifact change still rebuilds.  Also pins the new
/// per-model snapshot counters on the stats line.
#[test]
fn noop_reload_reports_rebuilt_false_on_the_wire() {
    let dir = model_dir("noop_wire");
    let coord = Arc::new(Coordinator::start(&sim_cfg(&dir, SnapshotMode::On)).unwrap());
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let mut c = Client::connect(&server.addr().to_string()).unwrap();

    // Load generation 1 lazily.
    let r = c.infer(&InferRequest::new(1).synthetic(5)).unwrap();
    assert!(r.ok, "{:?}", r.error);

    // Unchanged artifacts: generation bump, no rebuild, no warm time.
    let j = c.reload(Some(MODEL)).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{j:?}");
    assert_eq!(
        j.get("rebuilt").and_then(|v| v.as_bool()),
        Some(false),
        "no-op reload must not rebuild: {j:?}"
    );
    assert_eq!(j.f64_of("warm_ms").unwrap(), 0.0, "{j:?}");
    assert_eq!(j.usize_of("generation").unwrap(), 2, "{j:?}");

    // Serving is untouched by the no-op bump.
    let r = c.infer(&InferRequest::new(2).synthetic(6)).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.top1, expected_top1(MODEL, &frame_pixels(6), CLASSES));

    // A real artifact change still rebuilds.
    zuluko::testkit::manifest::write_synthetic(&dir, MODEL, CLASSES, HW, &[1, 2])
        .unwrap();
    let j = c.reload(Some(MODEL)).unwrap();
    assert_eq!(
        j.get("rebuilt").and_then(|v| v.as_bool()),
        Some(true),
        "changed artifacts must rebuild: {j:?}"
    );

    // The stats line carries the cold-start economics per model.
    let stats = c.stats().unwrap();
    let models = stats.get("models").and_then(|m| m.as_arr()).unwrap();
    let row = models
        .iter()
        .find(|m| m.str_of("model").ok() == Some(MODEL))
        .expect("model row");
    for key in [
        "snapshot_hits",
        "snapshot_misses",
        "snapshot_fallbacks",
        "prefetch_builds",
    ] {
        assert!(row.usize_of(key).is_ok(), "stats row missing {key}: {row:?}");
    }
    assert!(row.f64_of("warm_ms").is_ok(), "stats row missing warm_ms");

    drop(c);
    stop_all(server, coord);
}

/// Predictive warm-up: closed-loop traffic on one queue pushes its
/// arrival EWMA over the threshold, and workers that never served it
/// prefetch-build their replica (observable as `prefetch_builds`),
/// while every answer stays correct.
#[test]
fn predictive_prefetch_builds_replicas_on_idle_workers() {
    let dir = model_dir("prefetch");
    let mut cfg = sim_cfg(&dir, SnapshotMode::On);
    cfg.workers = 3;
    cfg.prefetch_threshold = 0.5;
    cfg.validate().unwrap();
    let coord = Coordinator::start(&cfg).unwrap();

    // Bursts of two concurrent requests: at most two of the three
    // workers are ever serving, so each burst leaves an idle worker —
    // and in the early bursts that worker has no cached replica, which
    // is exactly whom the (fleet-bounded) prefetch grants are for.
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut fired = false;
    let mut i = 0u64;
    while Instant::now() < deadline {
        let seeds = [10_000 + 2 * i, 10_001 + 2 * i];
        let pending: Vec<_> = seeds
            .iter()
            .map(|&seed| {
                coord
                    .submit_model(Some(MODEL), frame_tensor(seed), Slo::default())
                    .unwrap()
            })
            .collect();
        for (rx, &seed) in pending.iter().zip(&seeds) {
            let r = rx.recv().unwrap();
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(
                r.top1,
                expected_top1(MODEL, &frame_pixels(seed), CLASSES),
                "answer drifted while prefetch was active"
            );
        }
        i += 1;
        if model_stats(&coord).prefetch_builds >= 1 {
            fired = true;
            break;
        }
    }
    assert!(
        fired,
        "hot-queue traffic ({i} bursts) never triggered a prefetch build: {:?}",
        model_stats(&coord)
    );
    coord.shutdown();
}
