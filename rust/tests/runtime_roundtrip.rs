//! Integration: load AOT artifacts, execute on PJRT, compare to goldens.
//!
//! This is the correctness spine of the whole repro: if the HLO-text
//! bridge, the weight store, or the stage chain drift from the JAX oracle,
//! these tests catch it before any benchmark means anything.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use zuluko::runtime::{
    literal_from_tensor, run_timed, tensor_from_literal, Manifest, Runtime, WeightStore,
};
use zuluko::tensor::Tensor;

fn setup() -> Option<(Manifest, Runtime, WeightStore)> {
    let dir = zuluko::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first ({})", dir.display());
        return None;
    }
    let m = Manifest::load(&dir).expect("manifest");
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let w = WeightStore::load(&m).expect("weights");
    Some((m, rt, w))
}

#[test]
fn manifest_loads_and_validates() {
    let Some((m, _, _)) = setup() else { return };
    assert_eq!(m.model, "squeezenet-v1.0");
    assert_eq!(m.input_hw, 227);
    assert_eq!(m.num_classes, 1000);
    assert_eq!(m.stages.len(), 10);
    assert_eq!(m.probe_stages.len(), 15);
    assert_eq!(m.ops.len(), 66);
    assert_eq!(m.quant_ops.len(), 118);
    // 1.24M params ≈ the paper's "~5 MB fp32" SqueezeNet.
    let total: usize = m.params.iter().map(|p| p.nelems).sum();
    assert!((1_200_000..1_300_000).contains(&total), "params {total}");
}

#[test]
fn weights_load_with_expected_sizes() {
    let Some((m, _, w)) = setup() else { return };
    assert_eq!(w.total_f32_params(),
               m.params.iter().map(|p| p.nelems).sum::<usize>());
    // Spot-check a couple of shapes via literals.
    let conv1 = w.literal("conv1_w").unwrap();
    assert_eq!(conv1.element_count(), 7 * 7 * 3 * 96);
    let q8 = w.literal("fire2_sw_q8").unwrap();
    assert_eq!(q8.element_count(), 96 * 16);
}

#[test]
fn stage_chain_reproduces_golden_probs() {
    let Some((m, rt, w)) = setup() else { return };
    let input = Tensor::from_f32_file(&m.path(&m.golden.input), &[1, 227, 227, 3])
        .expect("golden input");
    let mut cur = literal_from_tensor(&input).unwrap();

    for st in &m.stages {
        let art = st.artifacts.get(&1).expect("b1 artifact");
        let exe = rt.load(&m.path(art)).expect("compile stage");
        let mut args: Vec<&xla::Literal> = Vec::new();
        for p in &st.params {
            args.push(w.literal(p).unwrap());
        }
        args.push(&cur);
        let (out, _t) = run_timed(&exe, &args).expect("stage exec");
        cur = out;
    }

    let probs = tensor_from_literal(&cur).unwrap();
    assert_eq!(probs.shape(), &[1, 1000]);
    let golden = Tensor::from_f32_file(&m.path(&m.golden.probs), &[1, 1000]).unwrap();
    let (abs, _rel) = probs.max_abs_rel_diff(&golden).unwrap();
    assert!(abs < 1e-3, "probs drift from oracle: max abs {abs}");
    assert_eq!(probs.argmax(), m.golden.top1, "top-1 mismatch");

    // Probabilities must sum to 1.
    let sum: f32 = probs.data().iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "prob sum {sum}");
}

#[test]
fn per_stage_outputs_match_stage_goldens() {
    let Some((m, rt, w)) = setup() else { return };
    let input = Tensor::from_f32_file(&m.path(&m.golden.input), &[1, 227, 227, 3]).unwrap();
    let mut cur = literal_from_tensor(&input).unwrap();

    for (st, gfile) in m.stages.iter().zip(&m.golden.stages) {
        let exe = rt.load(&m.path(st.artifacts.get(&1).unwrap())).unwrap();
        let mut args: Vec<&xla::Literal> = st
            .params
            .iter()
            .map(|p| w.literal(p).unwrap())
            .collect();
        args.push(&cur);
        let (out, _) = run_timed(&exe, &args).unwrap();

        let got = tensor_from_literal(&out).unwrap();
        let mut shape = vec![1usize];
        shape.extend(&st.out_shape);
        let want = Tensor::from_f32_file(&m.path(gfile), &shape)
            .unwrap_or_else(|e| panic!("golden {gfile}: {e}"));
        let (abs, _) = got.max_abs_rel_diff(&want).unwrap();
        // fp32 kernel-vs-oracle accumulation-order tolerance, growing with
        // depth; the softmax head renormalizes so the end stays tight.
        assert!(abs < 2e-2, "stage {} drift {abs}", st.name);
        cur = out;
    }
}

#[test]
fn fused_full_network_matches_staged() {
    let Some((m, rt, w)) = setup() else { return };
    let input = Tensor::from_f32_file(&m.path(&m.golden.input), &[1, 227, 227, 3]).unwrap();

    let full = rt.load(&m.path(m.full.get(&1).unwrap())).unwrap();
    let mut args: Vec<&xla::Literal> =
        m.params.iter().map(|p| w.literal(&p.name).unwrap()).collect();
    let inp = literal_from_tensor(&input).unwrap();
    args.push(&inp);
    let (out, _) = run_timed(&full, &args).unwrap();
    let probs = tensor_from_literal(&out).unwrap();

    let golden = Tensor::from_f32_file(&m.path(&m.golden.probs), &[1, 1000]).unwrap();
    let (abs, _) = probs.max_abs_rel_diff(&golden).unwrap();
    assert!(abs < 1e-3, "fused drift {abs}");
    assert_eq!(probs.argmax(), m.golden.top1);
}

#[test]
fn batch_variants_agree_with_batch1() {
    let Some((m, rt, w)) = setup() else { return };
    let img = Tensor::from_f32_file(&m.path(&m.golden.input), &[1, 227, 227, 3]).unwrap();
    let single = img.clone().reshape(&[227, 227, 3]).unwrap();

    // Pack the same image 4x; every row of the batch must match b1 output.
    let batch = Tensor::stack(&[&single, &single, &single, &single]).unwrap();
    let exe = rt.load(&m.path(m.full.get(&4).unwrap())).unwrap();
    let mut args: Vec<&xla::Literal> =
        m.params.iter().map(|p| w.literal(&p.name).unwrap()).collect();
    let blit = literal_from_tensor(&batch).unwrap();
    args.push(&blit);
    let (out, _) = run_timed(&exe, &args).unwrap();
    let probs = tensor_from_literal(&out).unwrap();
    assert_eq!(probs.shape(), &[4, 1000]);

    let golden = Tensor::from_f32_file(&m.path(&m.golden.probs), &[1, 1000]).unwrap();
    for row in probs.unstack().unwrap() {
        let row = row.reshape(&[1, 1000]).unwrap();
        let (abs, _) = row.max_abs_rel_diff(&golden).unwrap();
        assert!(abs < 1e-3, "batch row drift {abs}");
    }
}
